//! Deterministic fault injection with graceful-degradation scoring
//! (DESIGN.md §14).
//!
//! A [`FaultPlan`] is a list of typed [`FaultSpec`]s, each with an
//! activation window `[t0_ns, t1_ns)` and an optional tenant filter. Two
//! fault families exist:
//!
//! * **Sensor faults** — DVS dropout intervals, stuck/hot pixels,
//!   timestamp jitter, frame-sensor blackout. These are applied *between*
//!   the sensor front end ([`EventSource`]) and the DES: the source (live
//!   or trace replay) stays fault-free, so trace capture/replay
//!   bit-identity (DESIGN.md §9) is untouched and a faulted grid cell
//!   shares its capture with the healthy cells.
//! * **Engine faults** — brownout-at-low-rail dispatch stall, transient
//!   dispatch failure with bounded deterministic retry/backoff, DMA
//!   timeout. These surface through
//!   [`Engine::dispatch_faulted`](crate::coordinator::engine::Engine::dispatch_faulted)
//!   and the frame-DMA hook, so the coordinator observes them exactly
//!   where the hardware would: at the offload boundary.
//!
//! ## Determinism rules
//!
//! Everything is a pure function of `(config, seed, plan)`:
//!
//! * hot-pixel positions derive from a [`Rng`] seeded by
//!   `(run seed, spec index)` — never from host state;
//! * timestamp jitter is *hash-based* per event (FNV-1a of
//!   `(seed, t_ns, x, y)`), so it is independent of evaluation order;
//! * the transient-failure coin flips advance a per-spec PCG stream in
//!   DES dispatch order, which is itself deterministic;
//! * an **empty plan is bit-identical to no plan at all**: every hook
//!   checks activation before doing any arithmetic, and inactive specs
//!   take the exact same code path as absent ones
//!   (`tests/integration_faults.rs`, `prop_fault_free_plan_is_identity`).
//!
//! Retry/backoff bounds: a transient dispatch failure retries at most
//! [`RETRY_MAX`] times, each retry delaying the job start by one more
//! [`RETRY_BACKOFF_NS`]; a job that fails every attempt is dropped (and
//! counted as a deadline miss, like a backpressure drop).
//!
//! [`EventSource`]: crate::sensors::trace::EventSource

use crate::event::{Event, Polarity};
use crate::util::fnv1a;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// Maximum transient-dispatch retries before the job is dropped.
pub const RETRY_MAX: u32 = 3;
/// Deterministic backoff per retry (ns): retry `k` starts `k * backoff`
/// after the original dispatch instant.
pub const RETRY_BACKOFF_NS: u64 = 100_000;
/// Hot-pixel firing period (ns): each stuck pixel emits one spurious
/// event per millisecond while the spec is active.
pub const HOT_PIXEL_PERIOD_NS: u64 = 1_000_000;
/// Default stuck-pixel population for `hot_pixels` without an argument.
pub const DEFAULT_HOT_PIXELS: u32 = 8;
/// Default timestamp-jitter amplitude (us) for `jitter` without an
/// argument.
pub const DEFAULT_JITTER_US: f64 = 200.0;
/// Default brownout threshold (V): engine dispatch stalls while the
/// shared rail sits strictly below this.
pub const DEFAULT_BROWNOUT_VDD: f64 = 0.65;
/// Default transient dispatch-failure probability for `flaky`.
pub const DEFAULT_FLAKY_P: f64 = 0.1;
/// Default DMA-timeout penalty (us) added to the frame DMA completion.
pub const DEFAULT_DMA_PENALTY_US: f64 = 2_000.0;

/// Degradation-score weights (documented in DESIGN.md §14). Chosen so a
/// tenant untouched by any fault scores exactly 0.0.
const W_MISS: f64 = 1.0;
const W_EVENT: f64 = 0.01;
const W_STEER: f64 = 100.0;
const W_COLL: f64 = 10.0;
const W_RETRY: f64 = 0.5;
const W_BLACKOUT: f64 = 1.0;
const W_DEGRADED_MS: f64 = 0.05;

/// One typed fault. Parameters carry physical units in their names.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// DVS goes silent: every event inside the activation window is
    /// suppressed before it reaches the DES.
    DvsDropout,
    /// `pixels` stuck/hot DVS pixels fire spuriously at
    /// [`HOT_PIXEL_PERIOD_NS`] while active (positions seeded from the
    /// run seed + spec index).
    HotPixels { pixels: u32 },
    /// Per-event timestamp jitter, uniform in `[-amp_us, +amp_us]`,
    /// clamped to the scheduling window and re-sorted to stay monotonic.
    TimestampJitter { amp_us: f64 },
    /// The frame sensor yields nothing: captured frames inside the window
    /// are discarded before DMA (the frame job never runs — one deadline
    /// miss per blacked frame).
    FrameBlackout,
    /// Engines stall while the shared rail sits below `below_vdd`: each
    /// dispatch is delayed by one full scheduling window, which drives the
    /// job's slack negative — the signal a `DeadlineAware` governor
    /// escapes by raising the rail, and a `Fixed` one cannot.
    Brownout { below_vdd: f64 },
    /// Transient dispatch failure with probability `p` per attempt,
    /// retried deterministically up to [`RETRY_MAX`] times with
    /// [`RETRY_BACKOFF_NS`] linear backoff; exhausted retries drop the job.
    FlakyDispatch { p: f64 },
    /// Frame DMA completion is delayed by `penalty_us` (a bus timeout +
    /// replay), pushing the CUTIE/PULP forks toward their deadline.
    DmaTimeout { penalty_us: f64 },
}

impl FaultKind {
    /// Canonical spec name (the string [`FaultPlan::parse`] accepts).
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::DvsDropout => "dvs_dropout",
            FaultKind::HotPixels { .. } => "hot_pixels",
            FaultKind::TimestampJitter { .. } => "jitter",
            FaultKind::FrameBlackout => "frame_blackout",
            FaultKind::Brownout { .. } => "brownout",
            FaultKind::FlakyDispatch { .. } => "flaky",
            FaultKind::DmaTimeout { .. } => "dma_timeout",
        }
    }

    /// Is this a SoC-wide engine fault (tenant filter ignored)?
    pub fn is_soc_wide(&self) -> bool {
        matches!(self, FaultKind::Brownout { .. } | FaultKind::FlakyDispatch { .. })
    }
}

/// One fault with its activation window and tenant filter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Activation window start (ns of simulated mission time).
    pub t0_ns: u64,
    /// Activation window end (exclusive); `u64::MAX` = whole run.
    pub t1_ns: u64,
    /// Tenant this fault targets; `None` = every tenant. Ignored by
    /// SoC-wide faults ([`FaultKind::is_soc_wide`]).
    pub tenant: Option<usize>,
}

impl FaultSpec {
    /// A whole-run spec targeting tenant 0 (the CLI shorthand default for
    /// per-sensor faults) or the whole SoC for engine faults.
    pub fn whole_run(kind: FaultKind) -> FaultSpec {
        let tenant = if kind.is_soc_wide() { None } else { Some(0) };
        FaultSpec { kind, t0_ns: 0, t1_ns: u64::MAX, tenant }
    }

    /// Does the activation window overlap `[t0, t0 + span)`?
    fn overlaps(&self, t0: u64, span: u64) -> bool {
        self.t0_ns < t0.saturating_add(span) && self.t1_ns > t0
    }

    /// Is instant `t` inside the activation window?
    fn covers(&self, t: u64) -> bool {
        self.t0_ns <= t && t < self.t1_ns
    }

    /// Does this spec apply to `tenant` (SoC-wide faults apply to all)?
    fn applies_to(&self, tenant: usize) -> bool {
        self.kind.is_soc_wide() || self.tenant.is_none_or(|t| t == tenant)
    }

    /// Canonical text form, parseable by [`FaultPlan::parse`].
    pub fn label(&self) -> String {
        let mut s = match self.kind {
            FaultKind::DvsDropout | FaultKind::FrameBlackout => self.kind.name().to_string(),
            FaultKind::HotPixels { pixels } => format!("hot_pixels:{pixels}"),
            FaultKind::TimestampJitter { amp_us } => format!("jitter:{amp_us}"),
            FaultKind::Brownout { below_vdd } => format!("brownout:{below_vdd}"),
            FaultKind::FlakyDispatch { p } => format!("flaky:{p}"),
            FaultKind::DmaTimeout { penalty_us } => format!("dma_timeout:{penalty_us}"),
        };
        match self.tenant {
            Some(t) if !self.kind.is_soc_wide() => s.push_str(&format!("@{t}")),
            _ => {}
        }
        if self.t0_ns != 0 || self.t1_ns != u64::MAX {
            s.push_str(&format!("~{}-{}", self.t0_ns as f64 * 1e-9, self.t1_ns as f64 * 1e-9));
        }
        s
    }
}

/// An ordered list of fault specs — the per-run (or per-stream) plan.
/// The default (empty) plan is the healthy SoC, bit for bit.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// A single whole-run fault — the common CLI/bench shorthand.
    pub fn single(kind: FaultKind) -> FaultPlan {
        FaultPlan { specs: vec![FaultSpec::whole_run(kind)] }
    }

    /// Canonical text form: `none` for the empty plan, otherwise specs
    /// joined by `+` — round-trips through [`FaultPlan::parse`] and names
    /// grid cells (`faults=`).
    pub fn label(&self) -> String {
        if self.specs.is_empty() {
            "none".to_string()
        } else {
            self.specs.iter().map(|s| s.label()).collect::<Vec<_>>().join("+")
        }
    }

    /// Parse a plan spec: `none` (or empty) is the empty plan, otherwise
    /// `+`-joined fault tokens of the form `name[:arg][@tenant][~t0-t1]`
    /// with `t0`/`t1` in seconds. Per-sensor faults default to tenant 0
    /// (`@all` lifts the filter); engine faults are SoC-wide.
    ///
    /// Examples: `dvs_dropout`, `hot_pixels:16@1`, `brownout:0.65`,
    /// `jitter:500~0.2-0.8`, `dvs_dropout+flaky:0.2`.
    pub fn parse(s: &str) -> crate::Result<FaultPlan> {
        let s = s.trim();
        if s.is_empty() || s == "none" || s == "off" {
            return Ok(FaultPlan::default());
        }
        let mut specs = Vec::new();
        for token in s.split('+') {
            specs.push(Self::parse_spec(token.trim())?);
        }
        Ok(FaultPlan { specs })
    }

    fn parse_spec(token: &str) -> crate::Result<FaultSpec> {
        anyhow::ensure!(!token.is_empty(), "empty fault token");
        // peel the ~t0-t1 window, then the @tenant filter, then :arg
        let (head, window) = match token.split_once('~') {
            Some((h, w)) => (h, Some(w)),
            None => (token, None),
        };
        let (head, tenant_s) = match head.split_once('@') {
            Some((h, t)) => (h, Some(t)),
            None => (head, None),
        };
        let (name, arg) = match head.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (head, None),
        };
        let num = |a: &str, what: &str| -> crate::Result<f64> {
            let v: f64 = a
                .parse()
                .map_err(|_| anyhow::anyhow!("bad {what} '{a}' in fault '{token}'"))?;
            anyhow::ensure!(v.is_finite() && v >= 0.0, "{what} must be finite and >= 0, got {v}");
            Ok(v)
        };
        let kind = match name {
            "dvs_dropout" => {
                anyhow::ensure!(arg.is_none(), "dvs_dropout takes no argument");
                FaultKind::DvsDropout
            }
            "hot_pixels" => FaultKind::HotPixels {
                pixels: match arg {
                    Some(a) => num(a, "pixel count")? as u32,
                    None => DEFAULT_HOT_PIXELS,
                },
            },
            "jitter" => FaultKind::TimestampJitter {
                amp_us: match arg {
                    Some(a) => num(a, "jitter amplitude (us)")?,
                    None => DEFAULT_JITTER_US,
                },
            },
            "frame_blackout" => {
                anyhow::ensure!(arg.is_none(), "frame_blackout takes no argument");
                FaultKind::FrameBlackout
            }
            "brownout" => FaultKind::Brownout {
                below_vdd: match arg {
                    Some(a) => num(a, "brownout threshold (V)")?,
                    None => DEFAULT_BROWNOUT_VDD,
                },
            },
            "flaky" => {
                let p = match arg {
                    Some(a) => num(a, "failure probability")?,
                    None => DEFAULT_FLAKY_P,
                };
                anyhow::ensure!(p < 1.0, "flaky probability must be < 1, got {p}");
                FaultKind::FlakyDispatch { p }
            }
            "dma_timeout" => FaultKind::DmaTimeout {
                penalty_us: match arg {
                    Some(a) => num(a, "DMA penalty (us)")?,
                    None => DEFAULT_DMA_PENALTY_US,
                },
            },
            other => anyhow::bail!(
                "unknown fault '{other}' (dvs_dropout|hot_pixels|jitter|frame_blackout|\
                 brownout|flaky|dma_timeout)"
            ),
        };
        let tenant = match tenant_s {
            None => {
                if kind.is_soc_wide() {
                    None
                } else {
                    Some(0)
                }
            }
            Some("all") => None,
            Some(t) => Some(
                t.parse::<usize>()
                    .map_err(|_| anyhow::anyhow!("bad tenant '{t}' in fault '{token}'"))?,
            ),
        };
        let (t0_ns, t1_ns) = match window {
            None => (0, u64::MAX),
            Some(w) => {
                let (a, b) = w
                    .split_once('-')
                    .ok_or_else(|| anyhow::anyhow!("bad window '{w}' (want t0-t1 seconds)"))?;
                let t0 = num(a, "window start (s)")?;
                let t1 = num(b, "window end (s)")?;
                anyhow::ensure!(t1 > t0, "fault window must end after it starts");
                ((t0 * 1e9) as u64, (t1 * 1e9) as u64)
            }
        };
        Ok(FaultSpec { kind, t0_ns, t1_ns, tenant })
    }

    /// The exact-dedup union of several plans: fan-out replicates one
    /// mission plan into every stream, so the per-SoC session must not
    /// double-apply identical specs.
    pub fn union<'a>(plans: impl IntoIterator<Item = &'a FaultPlan>) -> FaultPlan {
        let mut specs: Vec<FaultSpec> = Vec::new();
        for plan in plans {
            for s in &plan.specs {
                if !specs.contains(s) {
                    specs.push(*s);
                }
            }
        }
        FaultPlan { specs }
    }

    /// Build the per-run injection state. `seed` is the run seed (stream 0
    /// for workloads), `window_ns` the scheduling quantum, `tenants` the
    /// stream count.
    pub fn session(&self, seed: u64, window_ns: u64, tenants: usize) -> FaultSession {
        FaultSession {
            specs: self.specs.clone(),
            seed,
            window_ns: window_ns.max(1),
            hot_pixels: vec![None; self.specs.len()],
            flaky_rng: self
                .specs
                .iter()
                .enumerate()
                .map(|(i, _)| Rng::seed_from_u64(mix(seed, i as u64)))
                .collect(),
            counters: FaultCounters::default(),
            per_tenant: vec![TenantFaultStats::default(); tenants.max(1)],
            last_degraded_win: vec![None; tenants.max(1)],
        }
    }
}

/// Mix a seed and a spec index into an independent RNG seed.
fn mix(seed: u64, idx: u64) -> u64 {
    seed ^ idx.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(17)
}

/// Order-independent per-event jitter offset in `[-amp_ns, +amp_ns]`.
fn jitter_offset_ns(seed: u64, e: &Event, amp_ns: u64) -> i64 {
    if amp_ns == 0 {
        return 0;
    }
    let mut buf = [0u8; 20];
    buf[..8].copy_from_slice(&seed.to_le_bytes());
    buf[8..16].copy_from_slice(&e.t_ns.to_le_bytes());
    buf[16..18].copy_from_slice(&e.x.to_le_bytes());
    buf[18..20].copy_from_slice(&e.y.to_le_bytes());
    let h = fnv1a(&buf);
    (h % (2 * amp_ns + 1)) as i64 - amp_ns as i64
}

/// Plan-level injection counters, accumulated over one run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultCounters {
    /// Spurious hot-pixel events added to the DES input.
    pub injected_events: u64,
    /// Real sensor events suppressed by dropout.
    pub suppressed_events: u64,
    /// Transient-failure retries that eventually dispatched.
    pub engine_retries: u64,
    /// Jobs dropped after exhausting every retry.
    pub engine_drops: u64,
    /// Dispatches stalled by a brownout.
    pub brownout_stalls: u64,
    /// Scheduling windows closed while a brownout held the rail hostage.
    pub brownout_epochs: u64,
    /// Frame DMAs hit by a timeout penalty.
    pub dma_timeouts: u64,
    /// Frames discarded by a sensor blackout.
    pub frames_blacked: u64,
}

/// Per-tenant fault attribution (feeds [`TenantDegradation`]).
#[derive(Debug, Clone, Copy, Default)]
struct TenantFaultStats {
    retries: u64,
    frames_blacked: u64,
    degraded_windows: u64,
}

/// What [`FaultSession::engine_gate`] decided for one dispatch attempt.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineGate {
    /// Transient failure exhausted its retries: drop the job.
    pub drop: bool,
    /// Total start delay (brownout stall + retry backoff), ns.
    pub delay_ns: u64,
    /// Retries spent before the verdict.
    pub retries: u32,
}

/// Live injection state for one run: the specs plus their seeded RNG
/// streams and the attribution counters. One session per SoC.
#[derive(Debug, Clone)]
pub struct FaultSession {
    specs: Vec<FaultSpec>,
    seed: u64,
    window_ns: u64,
    /// Lazily drawn stuck-pixel positions, one slot per spec.
    hot_pixels: Vec<Option<Vec<(u16, u16)>>>,
    /// Per-spec transient-failure coin streams.
    flaky_rng: Vec<Rng>,
    pub counters: FaultCounters,
    per_tenant: Vec<TenantFaultStats>,
    last_degraded_win: Vec<Option<u64>>,
}

impl FaultSession {
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Mark `tenant` degraded in the scheduling window containing `t_ns`
    /// (counted once per window).
    fn touch(&mut self, tenant: usize, t_ns: u64) {
        let t = tenant.min(self.per_tenant.len() - 1);
        let w = t_ns / self.window_ns;
        if self.last_degraded_win[t] != Some(w) {
            self.last_degraded_win[t] = Some(w);
            self.per_tenant[t].degraded_windows += 1;
        }
    }

    /// Apply the sensor faults to one captured window. Returns `true`
    /// when `out` holds the transformed stream (suppressions, injections
    /// and jitter applied, re-sorted); `false` leaves `evs` authoritative
    /// with zero work done — the empty/inactive-plan fast path.
    pub fn transform_window(
        &mut self,
        tenant: usize,
        dims: (usize, usize),
        t0: u64,
        window_ns: u64,
        evs: &[Event],
        out: &mut Vec<Event>,
    ) -> bool {
        let mut any = false;
        for s in &self.specs {
            if s.applies_to(tenant)
                && s.overlaps(t0, window_ns)
                && matches!(
                    s.kind,
                    FaultKind::DvsDropout
                        | FaultKind::HotPixels { .. }
                        | FaultKind::TimestampJitter { .. }
                )
            {
                any = true;
                break;
            }
        }
        if !any {
            return false;
        }

        out.clear();
        let t_end = t0 + window_ns;
        let mut suppressed = 0u64;
        let mut jittered = false;
        'events: for e in evs {
            let mut ev = *e;
            for s in &self.specs {
                if !s.applies_to(tenant) || !s.covers(e.t_ns) {
                    continue;
                }
                match s.kind {
                    FaultKind::DvsDropout => {
                        suppressed += 1;
                        continue 'events;
                    }
                    FaultKind::TimestampJitter { amp_us } => {
                        let amp_ns = (amp_us * 1e3) as u64;
                        let off = jitter_offset_ns(self.seed, e, amp_ns);
                        ev.t_ns = ev
                            .t_ns
                            .saturating_add_signed(off)
                            .clamp(t0, t_end.saturating_sub(1));
                        jittered = true;
                    }
                    _ => {}
                }
            }
            out.push(ev);
        }

        // hot pixels: spurious events on the stuck positions, one per
        // period tick inside (activation window ∩ this window)
        let mut injected = 0u64;
        for i in 0..self.specs.len() {
            let s = self.specs[i];
            let FaultKind::HotPixels { pixels } = s.kind else { continue };
            if !s.applies_to(tenant) || !s.overlaps(t0, window_ns) {
                continue;
            }
            let px = self.hot_pixels[i].get_or_insert_with(|| {
                let (w, h) = dims;
                let mut rng = Rng::seed_from_u64(mix(self.seed, i as u64));
                (0..pixels)
                    .map(|_| {
                        (
                            rng.gen_below(w.max(1) as u64) as u16,
                            rng.gen_below(h.max(1) as u64) as u16,
                        )
                    })
                    .collect()
            });
            let lo = t0.max(s.t0_ns);
            let hi = t_end.min(s.t1_ns);
            let mut k = lo.div_ceil(HOT_PIXEL_PERIOD_NS);
            while k * HOT_PIXEL_PERIOD_NS < hi {
                let t = k * HOT_PIXEL_PERIOD_NS;
                for &(x, y) in px.iter() {
                    out.push(Event { t_ns: t, x, y, polarity: Polarity::On });
                    injected += 1;
                }
                k += 1;
            }
        }

        if jittered || injected > 0 {
            out.sort_by_key(|e| e.t_ns);
        }
        self.counters.suppressed_events += suppressed;
        self.counters.injected_events += injected;
        if suppressed > 0 || injected > 0 || jittered {
            self.touch(tenant, t0);
        }
        true
    }

    /// Is the frame captured at `fts` for `tenant` blacked out?
    pub fn frame_blacked(&mut self, tenant: usize, fts: u64) -> bool {
        let hit = self.specs.iter().any(|s| {
            matches!(s.kind, FaultKind::FrameBlackout) && s.applies_to(tenant) && s.covers(fts)
        });
        if hit {
            self.counters.frames_blacked += 1;
            let t = tenant.min(self.per_tenant.len() - 1);
            self.per_tenant[t].frames_blacked += 1;
            self.touch(tenant, fts);
        }
        hit
    }

    /// Apply any active DMA-timeout penalty to a frame DMA completion.
    pub fn dma_delay(&mut self, tenant: usize, done_ns: u64) -> u64 {
        let mut done = done_ns;
        let mut hit = false;
        for s in &self.specs {
            let FaultKind::DmaTimeout { penalty_us } = s.kind else { continue };
            if s.applies_to(tenant) && s.covers(done_ns) {
                done = done.saturating_add((penalty_us * 1e3) as u64);
                hit = true;
            }
        }
        if hit {
            self.counters.dma_timeouts += 1;
            self.touch(tenant, done_ns);
        }
        done
    }

    /// Gate one engine dispatch: brownout stall (one scheduling window of
    /// start delay while the rail sits below the threshold) plus the
    /// transient-failure retry loop. Pure bookkeeping — the caller (the
    /// [`Engine::dispatch_faulted`] default) applies the verdict.
    ///
    /// [`Engine::dispatch_faulted`]: crate::coordinator::engine::Engine::dispatch_faulted
    pub fn engine_gate(
        &mut self,
        tenant: usize,
        now_ns: u64,
        vdd: f64,
        window_ns: u64,
    ) -> EngineGate {
        let mut gate = EngineGate::default();
        let mut hit = false;
        for i in 0..self.specs.len() {
            let s = self.specs[i];
            if !s.covers(now_ns) {
                continue;
            }
            match s.kind {
                FaultKind::Brownout { below_vdd } => {
                    if vdd < below_vdd {
                        gate.delay_ns += window_ns;
                        self.counters.brownout_stalls += 1;
                        hit = true;
                    }
                }
                FaultKind::FlakyDispatch { p } => {
                    let rng = &mut self.flaky_rng[i];
                    let mut attempts = 0u32;
                    loop {
                        let failed = rng.gen_f64() < p;
                        if !failed {
                            break;
                        }
                        attempts += 1;
                        if attempts > RETRY_MAX {
                            gate.drop = true;
                            break;
                        }
                    }
                    if attempts > 0 {
                        let retries = attempts.min(RETRY_MAX);
                        gate.retries += retries;
                        gate.delay_ns += retries as u64 * RETRY_BACKOFF_NS;
                        self.counters.engine_retries += retries as u64;
                        let t = tenant.min(self.per_tenant.len() - 1);
                        self.per_tenant[t].retries += retries as u64;
                        hit = true;
                    }
                    if gate.drop {
                        self.counters.engine_drops += 1;
                    }
                }
                _ => {}
            }
        }
        if hit {
            self.touch(tenant, now_ns);
        }
        gate
    }

    /// Epoch tick (call at every window close): counts windows spent with
    /// a brownout active at the current rail.
    pub fn note_epoch(&mut self, t1_ns: u64, vdd: f64) {
        let browned = self.specs.iter().any(|s| {
            matches!(s.kind, FaultKind::Brownout { below_vdd } if vdd < below_vdd)
                && s.covers(t1_ns.saturating_sub(1))
        });
        if browned {
            self.counters.brownout_epochs += 1;
        }
    }

    /// Time tenant `t` spent in degraded windows (ms).
    pub fn degraded_ms(&self, tenant: usize) -> f64 {
        self.per_tenant
            .get(tenant)
            .map_or(0.0, |s| s.degraded_windows as f64 * self.window_ns as f64 * 1e-6)
    }

    pub fn tenant_retries(&self, tenant: usize) -> u64 {
        self.per_tenant.get(tenant).map_or(0, |s| s.retries)
    }

    pub fn tenant_frames_blacked(&self, tenant: usize) -> u64 {
        self.per_tenant.get(tenant).map_or(0, |s| s.frames_blacked)
    }
}

/// The per-tenant observables the degradation score compares between the
/// faulted run and its fault-free twin. Both mission and workload reports
/// lower onto this shape.
#[derive(Debug, Clone, Default)]
pub struct TenantObservation {
    pub deadline_misses: u64,
    pub events_total: u64,
    pub avoid_fraction: f64,
    /// Steer values of the first recorded commands (bounded sample).
    pub steers: Vec<f32>,
}

/// One tenant's graceful-degradation scorecard.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantDegradation {
    pub tenant: usize,
    /// Extra deadline misses vs the fault-free twin (saturating at 0).
    pub deadline_misses: u64,
    /// Mean |Δ steer| over the paired command sample.
    pub steer_divergence: f64,
    /// |Δ avoid_fraction| vs the twin — collision-behaviour divergence.
    pub collision_divergence: f64,
    /// Twin events minus faulted events (negative = spurious injection).
    pub events_lost: i64,
    /// Engine retries attributed to this tenant.
    pub retries: u64,
    pub frames_blacked: u64,
    /// Time spent in windows where a fault touched this tenant (ms).
    pub degraded_ms: f64,
    /// The weighted rollup; exactly 0.0 for an untouched tenant.
    pub score: f64,
}

impl TenantDegradation {
    /// Score one tenant: faulted run vs its fault-free twin plus the
    /// session's attribution counters.
    pub fn from_observations(
        tenant: usize,
        baseline: &TenantObservation,
        faulted: &TenantObservation,
        session: &FaultSession,
    ) -> TenantDegradation {
        let misses = faulted.deadline_misses.saturating_sub(baseline.deadline_misses);
        let n = baseline.steers.len().min(faulted.steers.len());
        let steer_divergence = if n == 0 {
            0.0
        } else {
            baseline.steers[..n]
                .iter()
                .zip(&faulted.steers[..n])
                .map(|(a, b)| (a - b).abs() as f64)
                .sum::<f64>()
                / n as f64
        };
        let collision_divergence = (faulted.avoid_fraction - baseline.avoid_fraction).abs();
        let events_lost = baseline.events_total as i64 - faulted.events_total as i64;
        let retries = session.tenant_retries(tenant);
        let frames_blacked = session.tenant_frames_blacked(tenant);
        let degraded_ms = session.degraded_ms(tenant);
        let score = W_MISS * misses as f64
            + W_EVENT * events_lost.unsigned_abs() as f64
            + W_STEER * steer_divergence
            + W_COLL * collision_divergence
            + W_RETRY * retries as f64
            + W_BLACKOUT * frames_blacked as f64
            + W_DEGRADED_MS * degraded_ms;
        TenantDegradation {
            tenant,
            deadline_misses: misses,
            steer_divergence,
            collision_divergence,
            events_lost,
            retries,
            frames_blacked,
            degraded_ms,
            score,
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("tenant", Value::Num(self.tenant as f64)),
            ("deadline_misses", Value::Num(self.deadline_misses as f64)),
            ("steer_divergence", Value::Num(self.steer_divergence)),
            ("collision_divergence", Value::Num(self.collision_divergence)),
            ("events_lost", Value::Num(self.events_lost as f64)),
            ("retries", Value::Num(self.retries as f64)),
            ("frames_blacked", Value::Num(self.frames_blacked as f64)),
            ("degraded_ms", Value::Num(self.degraded_ms)),
            ("score", Value::Num(self.score)),
        ])
    }
}

/// The resilience rollup a faulted run attaches to its report: plan-level
/// injection counters plus one [`TenantDegradation`] per tenant, scored
/// against an inline fault-free twin of the same config. Deterministic for
/// `(config, seed, plan)` on any worker count. Absent (and the report
/// byte-identical to the healthy pipeline) when the plan is empty.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceReport {
    pub plan: String,
    pub counters: FaultCounters,
    pub tenants: Vec<TenantDegradation>,
}

impl ResilienceReport {
    /// Build from the faulted/baseline observation pairs.
    pub fn score(
        plan: &FaultPlan,
        session: &FaultSession,
        baseline: &[TenantObservation],
        faulted: &[TenantObservation],
    ) -> ResilienceReport {
        debug_assert_eq!(baseline.len(), faulted.len());
        let tenants = baseline
            .iter()
            .zip(faulted)
            .enumerate()
            .map(|(i, (b, f))| TenantDegradation::from_observations(i, b, f, session))
            .collect();
        ResilienceReport { plan: plan.label(), counters: session.counters, tenants }
    }

    /// Tenants whose degradation score is nonzero.
    pub fn degraded_tenants(&self) -> u64 {
        self.tenants.iter().filter(|t| t.score > 0.0).count() as u64
    }

    /// Total degradation score across tenants — the governor-comparison
    /// metric of the e2e resilience bench.
    pub fn total_score(&self) -> f64 {
        self.tenants.iter().map(|t| t.score).sum()
    }

    pub fn to_json(&self) -> Value {
        let c = &self.counters;
        Value::obj(vec![
            ("plan", Value::Str(self.plan.clone())),
            ("injected_events", Value::Num(c.injected_events as f64)),
            ("suppressed_events", Value::Num(c.suppressed_events as f64)),
            ("engine_retries", Value::Num(c.engine_retries as f64)),
            ("engine_drops", Value::Num(c.engine_drops as f64)),
            ("brownout_stalls", Value::Num(c.brownout_stalls as f64)),
            ("brownout_epochs", Value::Num(c.brownout_epochs as f64)),
            ("dma_timeouts", Value::Num(c.dma_timeouts as f64)),
            ("frames_blacked", Value::Num(c.frames_blacked as f64)),
            ("degraded_tenants", Value::Num(self.degraded_tenants() as f64)),
            ("total_score", Value::Num(self.total_score())),
            ("tenants", Value::Arr(self.tenants.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t_ns: u64, x: u16, y: u16) -> Event {
        Event { t_ns, x, y, polarity: Polarity::On }
    }

    #[test]
    fn parse_round_trips_through_labels() {
        for s in [
            "dvs_dropout",
            "hot_pixels:16@1",
            "jitter:500",
            "frame_blackout@2",
            "brownout:0.65",
            "flaky:0.2",
            "dma_timeout:1500",
            "dvs_dropout+flaky:0.2",
            "jitter:250~0.2-0.8",
        ] {
            let plan = FaultPlan::parse(s).unwrap();
            let again = FaultPlan::parse(&plan.label()).unwrap();
            assert_eq!(plan, again, "label round-trip broke for '{s}'");
        }
        assert!(FaultPlan::parse("none").unwrap().is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(FaultPlan::default().label(), "none");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("warp_core_breach").is_err());
        assert!(FaultPlan::parse("flaky:1.5").is_err());
        assert!(FaultPlan::parse("jitter:nan").is_err());
        assert!(FaultPlan::parse("jitter:-5").is_err());
        assert!(FaultPlan::parse("dvs_dropout~2-1").is_err());
        assert!(FaultPlan::parse("dvs_dropout@x").is_err());
        assert!(FaultPlan::parse("dvs_dropout:3").is_err());
    }

    #[test]
    fn sensor_fault_defaults_to_tenant_zero_engine_faults_to_all() {
        let p = FaultPlan::parse("dvs_dropout").unwrap();
        assert_eq!(p.specs[0].tenant, Some(0));
        let p = FaultPlan::parse("dvs_dropout@all").unwrap();
        assert_eq!(p.specs[0].tenant, None);
        let p = FaultPlan::parse("brownout").unwrap();
        assert_eq!(p.specs[0].tenant, None);
        assert!(p.specs[0].kind.is_soc_wide());
    }

    #[test]
    fn inactive_specs_leave_the_window_untouched() {
        // a spec whose activation window sits beyond the run must take the
        // zero-work path: transform returns false, gates return zeros
        let plan = FaultPlan::parse("dvs_dropout~100-200").unwrap();
        let mut s = plan.session(7, 10_000_000, 1);
        let evs = [ev(1_000, 3, 4), ev(2_000, 5, 6)];
        let mut out = Vec::new();
        assert!(!s.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out));
        assert!(out.is_empty());
        let g = s.engine_gate(0, 0, 0.8, 10_000_000);
        assert!(!g.drop);
        assert_eq!((g.delay_ns, g.retries), (0, 0));
        assert_eq!(s.dma_delay(0, 5_000), 5_000);
        assert!(!s.frame_blacked(0, 5_000));
        assert_eq!(s.counters, FaultCounters::default());
    }

    #[test]
    fn dropout_suppresses_only_covered_events() {
        let plan = FaultPlan::parse("dvs_dropout~0-0.000002").unwrap(); // [0, 2000) ns
        let mut s = plan.session(7, 10_000_000, 1);
        let evs = [ev(1_000, 1, 1), ev(2_000, 2, 2), ev(3_000, 3, 3)];
        let mut out = Vec::new();
        assert!(s.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out));
        assert_eq!(out, vec![ev(2_000, 2, 2), ev(3_000, 3, 3)]);
        assert_eq!(s.counters.suppressed_events, 1);
        assert!(s.degraded_ms(0) > 0.0);
    }

    #[test]
    fn dropout_respects_the_tenant_filter() {
        let plan = FaultPlan::parse("dvs_dropout@1").unwrap();
        let mut s = plan.session(7, 10_000_000, 2);
        let evs = [ev(1_000, 1, 1)];
        let mut out = Vec::new();
        assert!(!s.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out));
        assert!(s.transform_window(1, (132, 128), 0, 10_000_000, &evs, &mut out));
        assert!(out.is_empty());
        assert_eq!(s.counters.suppressed_events, 1);
        assert_eq!(s.degraded_ms(0), 0.0);
        assert!(s.degraded_ms(1) > 0.0);
    }

    #[test]
    fn hot_pixels_inject_deterministic_sorted_events() {
        let plan = FaultPlan::parse("hot_pixels:4").unwrap();
        let run = || {
            let mut s = plan.session(42, 10_000_000, 1);
            let evs = [ev(500_000, 1, 1), ev(9_500_000, 2, 2)];
            let mut out = Vec::new();
            assert!(s.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out));
            (out, s.counters.injected_events)
        };
        let (a, na) = run();
        let (b, nb) = run();
        assert_eq!(a, b, "hot-pixel injection must be deterministic");
        assert_eq!(na, nb);
        // 9 ticks (1..=9 ms) x 4 pixels, plus the two real events
        assert_eq!(na, 36);
        assert_eq!(a.len(), 38);
        assert!(a.windows(2).all(|w| w[0].t_ns <= w[1].t_ns), "must stay sorted");
        for e in &a {
            assert!((e.x as usize) < 132 && (e.y as usize) < 128);
        }
    }

    #[test]
    fn jitter_is_order_independent_and_clamped() {
        let plan = FaultPlan::parse("jitter:100").unwrap();
        let evs = [ev(50_000, 1, 1), ev(5_000_000, 2, 2), ev(9_990_000, 3, 3)];
        let mut s1 = plan.session(7, 10_000_000, 1);
        let mut out1 = Vec::new();
        assert!(s1.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out1));
        // same events presented in a different order jitter identically
        let rev = [evs[2], evs[1], evs[0]];
        let mut s2 = plan.session(7, 10_000_000, 1);
        let mut out2 = Vec::new();
        assert!(s2.transform_window(0, (132, 128), 0, 10_000_000, &rev, &mut out2));
        let mut o1 = out1.clone();
        let mut o2 = out2.clone();
        o1.sort_by_key(|e| (e.t_ns, e.x));
        o2.sort_by_key(|e| (e.t_ns, e.x));
        assert_eq!(o1, o2, "jitter must be hash-based, not order-based");
        for e in &out1 {
            assert!(e.t_ns < 10_000_000, "jitter escaped the window");
        }
        assert!(out1.windows(2).all(|w| w[0].t_ns <= w[1].t_ns));
    }

    #[test]
    fn brownout_stalls_below_threshold_only() {
        let plan = FaultPlan::parse("brownout:0.65").unwrap();
        let mut s = plan.session(7, 10_000_000, 1);
        let g = s.engine_gate(0, 0, 0.8, 10_000_000);
        assert_eq!(g.delay_ns, 0);
        let g = s.engine_gate(0, 0, 0.6, 10_000_000);
        assert_eq!(g.delay_ns, 10_000_000);
        assert_eq!(s.counters.brownout_stalls, 1);
        s.note_epoch(10_000_000, 0.6);
        s.note_epoch(20_000_000, 0.8);
        assert_eq!(s.counters.brownout_epochs, 1);
    }

    #[test]
    fn flaky_retries_are_bounded_and_deterministic() {
        let plan = FaultPlan::parse("flaky:0.9").unwrap();
        let run = || {
            let mut s = plan.session(7, 10_000_000, 1);
            let mut drops = 0u64;
            let mut retries = 0u64;
            let mut max_delay = 0u64;
            for i in 0..200u64 {
                let g = s.engine_gate(0, i * 1_000, 0.8, 10_000_000);
                if g.drop {
                    drops += 1;
                }
                retries += g.retries as u64;
                max_delay = max_delay.max(g.delay_ns);
                assert!(g.retries <= RETRY_MAX);
            }
            (drops, retries, max_delay, s.counters)
        };
        let a = run();
        assert_eq!(a, run(), "flaky stream must replay bit-identically");
        assert!(a.0 > 0, "p=0.9 must exhaust retries sometimes");
        assert!(a.1 > 0);
        assert!(a.2 <= RETRY_MAX as u64 * RETRY_BACKOFF_NS);
        assert_eq!(a.3.engine_drops, a.0);
    }

    #[test]
    fn dma_timeout_delays_completion() {
        let plan = FaultPlan::parse("dma_timeout:1000").unwrap();
        let mut s = plan.session(7, 10_000_000, 1);
        assert_eq!(s.dma_delay(0, 5_000), 1_005_000);
        assert_eq!(s.counters.dma_timeouts, 1);
    }

    #[test]
    fn frame_blackout_hits_covered_frames() {
        let plan = FaultPlan::parse("frame_blackout~0-0.1").unwrap();
        let mut s = plan.session(7, 10_000_000, 1);
        assert!(s.frame_blacked(0, 50_000_000));
        assert!(!s.frame_blacked(0, 150_000_000));
        assert_eq!(s.counters.frames_blacked, 1);
        assert_eq!(s.tenant_frames_blacked(0), 1);
    }

    #[test]
    fn union_dedups_fanned_out_plans() {
        let p = FaultPlan::parse("dvs_dropout+brownout:0.65").unwrap();
        let copies = vec![p.clone(), p.clone(), p.clone()];
        let u = FaultPlan::union(copies.iter());
        assert_eq!(u, p, "fan-out copies must not double-apply");
    }

    #[test]
    fn untouched_tenant_scores_exactly_zero() {
        let plan = FaultPlan::parse("dvs_dropout").unwrap();
        let session = plan.session(7, 10_000_000, 2);
        let base = TenantObservation {
            deadline_misses: 3,
            events_total: 1000,
            avoid_fraction: 0.25,
            steers: vec![0.1, -0.2, 0.3],
        };
        let d = TenantDegradation::from_observations(1, &base, &base.clone(), &session);
        assert_eq!(d.score, 0.0);
        assert_eq!(d.deadline_misses, 0);
        assert_eq!(d.events_lost, 0);
    }

    #[test]
    fn degradation_scores_what_changed() {
        let plan = FaultPlan::parse("dvs_dropout").unwrap();
        let mut session = plan.session(7, 10_000_000, 1);
        let evs = [ev(1_000, 1, 1), ev(2_000, 2, 2)];
        let mut out = Vec::new();
        assert!(session.transform_window(0, (132, 128), 0, 10_000_000, &evs, &mut out));
        let base = TenantObservation {
            deadline_misses: 1,
            events_total: 1000,
            avoid_fraction: 0.2,
            steers: vec![0.1, 0.2],
        };
        let faulted = TenantObservation {
            deadline_misses: 4,
            events_total: 600,
            avoid_fraction: 0.5,
            steers: vec![0.3, 0.2],
        };
        let r = ResilienceReport::score(&plan, &session, &[base], &[faulted]);
        assert_eq!(r.tenants.len(), 1);
        let t = &r.tenants[0];
        assert_eq!(t.deadline_misses, 3);
        assert_eq!(t.events_lost, 400);
        assert!(t.steer_divergence > 0.0);
        assert!(t.collision_divergence > 0.0);
        assert!(t.score > 0.0);
        assert_eq!(r.degraded_tenants(), 1);
        assert!(r.total_score() >= t.score);
        let json = r.to_json();
        assert_eq!(json.get("degraded_tenants").and_then(Value::as_f64), Some(1.0));
        assert!(json.get("tenants").and_then(|v| v.as_arr()).is_some());
        assert_eq!(json.get("plan").and_then(Value::as_str), Some("dvs_dropout@0"));
    }
}
