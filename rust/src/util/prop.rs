//! Tiny property-testing driver.
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` random
//! inputs drawn from a deterministic seed sequence; on failure it reports
//! the failing case index and seed so the case replays exactly. No
//! shrinking — generators here are small enough that the raw failing seed
//! is directly debuggable.

use crate::util::rng::Rng;

/// Run `f` for `cases` seeded cases; panic with the failing seed on error.
///
/// `f` returns `Err(msg)` (or panics) to fail a case.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9E3779B9u64
            .wrapping_mul(case + 1)
            .wrapping_add(0xB5297A4D);
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("addition commutes", 50, |rng| {
            let a = rng.gen_range_f64(-1e6, 1e6);
            let b = rng.gen_range_f64(-1e6, 1e6);
            if a + b == b + a {
                Ok(())
            } else {
                Err("no".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 3, |_| Err("boom".into()));
    }

    #[test]
    fn cases_see_different_seeds() {
        let mut seen = Vec::new();
        check("seeds differ", 5, |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        seen.dedup();
        assert_eq!(seen.len(), 5);
    }
}
