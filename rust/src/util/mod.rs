//! In-repo substrates the offline build provides for itself:
//!
//! * [`rng`] — a deterministic PCG-family PRNG (the simulators' seed
//!   discipline depends on exact reproducibility across runs/platforms).
//! * [`json`] — a minimal JSON parser/printer for the artifact manifest,
//!   SoC config files, and `--json` CLI output.
//! * [`bench`] — the micro-benchmark harness used by `cargo bench`
//!   (`harness = false` targets): warmup, repetitions, median/mean/p95.
//! * [`prop`] — a tiny property-testing driver (randomized cases with
//!   shrink-free minimal reporting) used by `rust/tests/prop_invariants.rs`.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Value;
pub use rng::Rng;

/// 64-bit FNV-1a — the canonical-key hash shared by the serve result
/// cache, the sensor-trace cache and the trace keys themselves.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}
