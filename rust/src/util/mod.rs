//! In-repo substrates the offline build provides for itself:
//!
//! * [`rng`] — a deterministic PCG-family PRNG (the simulators' seed
//!   discipline depends on exact reproducibility across runs/platforms).
//! * [`json`] — a minimal JSON parser/printer for the artifact manifest,
//!   SoC config files, and `--json` CLI output.
//! * [`bench`] — the micro-benchmark harness used by `cargo bench`
//!   (`harness = false` targets): warmup, repetitions, median/mean/p95.
//! * [`prop`] — a tiny property-testing driver (randomized cases with
//!   shrink-free minimal reporting) used by `rust/tests/prop_invariants.rs`.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;

pub use json::Value;
pub use rng::Rng;

/// 64-bit FNV-1a — the canonical-key hash shared by the serve result
/// cache, the sensor-trace cache and the trace keys themselves.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.digest()
}

/// Streaming 64-bit FNV-1a. Feeding bytes through any sequence of
/// [`Fnv1a::update`] calls digests to the same value as one
/// [`fnv1a`] call over the concatenation, so the trace-store writer can
/// checksum sections as it serializes them without staging a copy.
#[derive(Debug, Clone)]
pub struct Fnv1a {
    h: u64,
    len: u64,
}

impl Fnv1a {
    const BASIS: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;

    #[allow(clippy::new_without_default)]
    pub fn new() -> Fnv1a {
        Fnv1a { h: Self::BASIS, len: 0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h ^= b as u64;
            self.h = self.h.wrapping_mul(Self::PRIME);
        }
        self.len += bytes.len() as u64;
    }

    /// Plain FNV-1a of everything fed so far.
    pub fn digest(&self) -> u64 {
        self.h
    }

    /// Length-mixed digest: the stream length (LE bytes) is folded in as
    /// a trailing block. Plain FNV-1a maps every prefix of zero bytes to
    /// a hash reachable from a shorter input, so a truncated-then-padded
    /// section could collide with its original; mixing the length in
    /// breaks that class. This is the on-disk section checksum of the
    /// trace/result store (`crate::store`).
    pub fn digest_len(&self) -> u64 {
        let mut tail = Fnv1a { h: self.h, len: 0 };
        tail.update(&self.len.to_le_bytes());
        tail.h
    }
}

/// One-shot length-mixed FNV-1a-64 (see [`Fnv1a::digest_len`]).
pub fn fnv1a_len(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.digest_len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_pins_known_vectors() {
        // reference vectors from the FNV test suite (Noll's fnv64a)
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn streaming_digest_matches_one_shot_for_any_chunking() {
        let data = b"kraken sensor trace section checksum";
        for split in 0..data.len() {
            let mut h = Fnv1a::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.digest(), fnv1a(data), "split at {split}");
            assert_eq!(h.digest_len(), fnv1a_len(data), "split at {split}");
        }
    }

    #[test]
    fn length_mixing_separates_padded_prefixes() {
        // plain FNV-1a of "" extended by the length block must differ from
        // the plain digest, and two streams that collide by zero-padding
        // tricks separate once length is mixed in
        assert_eq!(fnv1a_len(b""), fnv1a(&0u64.to_le_bytes()));
        assert_ne!(fnv1a_len(b""), fnv1a(b""));
        assert_ne!(fnv1a_len(b"\0"), fnv1a_len(b"\0\0"));
    }
}
