//! Deterministic PRNG: PCG-XSH-RR 64/32 seeded via SplitMix64.
//!
//! Every stochastic component of the simulation (DVS noise, scene
//! obstacles, synthetic datasets, property tests) draws from this
//! generator, so a (seed, config) pair reproduces a mission bit-exactly on
//! any platform — the property the determinism tests pin.

/// PCG32 state.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MUL: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f64 in [lo, hi).
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn gen_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return hi;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn gen_range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_below((hi - lo) as u64) as usize
    }

    pub fn gen_bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// Standard normal via Box–Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-300);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::seed_from_u64(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_below_unbiased_coverage() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.gen_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gen_normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_helpers() {
        let mut r = Rng::seed_from_u64(5);
        for _ in 0..1000 {
            let x = r.gen_range_f64(-0.6, 0.6);
            assert!((-0.6..0.6).contains(&x));
            let k = r.gen_range_usize(3, 9);
            assert!((3..9).contains(&k));
        }
    }
}
