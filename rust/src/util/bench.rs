//! Micro-benchmark harness for the `harness = false` bench targets.
//!
//! Deliberately criterion-shaped: warmup, then timed repetitions, then a
//! robust summary (median / mean / p95 / throughput). Wall-clock only —
//! the *simulated*-time results the paper cares about come from the models
//! themselves; this harness measures the simulator's own hot paths for the
//! §Perf optimization pass.
//!
//! [`BenchLog`] adds a machine-readable spine: a bench target built over
//! it (`cargo bench --bench hotpath -- --json`) writes
//! `BENCH_<name>.json` with per-section ns/op plus a provenance header
//! (`meta`: git commit, rustc version, enabled cargo features), so the
//! perf trajectory is tracked across PRs and every logged number ties
//! back to the code that produced it (CI uploads the file as an artifact
//! — EXPERIMENTS.md §Perf).

use std::time::Instant;

use crate::util::json::Value;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} iters   median {:>12}   mean {:>12}   p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns)
        )
    }

    /// A single-point measurement (e.g. one sweep's wall time) in result
    /// form, so point metrics and timed loops share the JSON schema.
    pub fn point(name: &str, ns: f64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 1,
            median_ns: ns,
            mean_ns: ns,
            p95_ns: ns,
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("iters", Value::Num(self.iters as f64)),
            ("median_ns", Value::Num(self.median_ns)),
            ("mean_ns", Value::Num(self.mean_ns)),
            ("p95_ns", Value::Num(self.p95_ns)),
        ])
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{:.0} ns", ns)
    }
}

/// Time `f` for at least `min_iters` iterations and ~`min_time_s` seconds
/// (whichever is more), after a short warmup. Prints and returns the
/// summary. A `black_box`-style sink prevents the optimizer from deleting
/// the measured work: have `f` return something and it is consumed here.
pub fn bench<T>(name: &str, mut f: impl FnMut() -> T) -> BenchResult {
    bench_cfg(name, 20, 0.25, &mut f)
}

/// Fully-parameterized variant.
pub fn bench_cfg<T>(
    name: &str,
    min_iters: usize,
    min_time_s: f64,
    f: &mut impl FnMut() -> T,
) -> BenchResult {
    // warmup
    for _ in 0..3 {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let median = samples[n / 2];
    let mean = samples.iter().sum::<f64>() / n as f64;
    let p95 = samples[((n as f64 * 0.95) as usize).min(n - 1)];
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        median_ns: median,
        mean_ns: mean,
        p95_ns: p95,
    };
    println!("{}", r.line());
    r
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// First line of `cmd args...` stdout, or `"unknown"` — bench metadata
/// must degrade gracefully on hosts without git/rustc in PATH (or outside
/// a checkout) rather than fail the bench run.
fn tool_line(cmd: &str, args: &[&str]) -> String {
    std::process::Command::new(cmd)
        .args(args)
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| {
            String::from_utf8(o.stdout)
                .ok()
                .and_then(|s| s.lines().next().map(|l| l.trim().to_string()))
        })
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The provenance header stamped into every `BENCH_*.json`: git commit,
/// rustc version, and the enabled cargo features — so a logged number can
/// always be tied back to the exact code and toolchain that produced it.
fn meta_json() -> Value {
    let features: Vec<Value> = [("scalar-ref", cfg!(feature = "scalar-ref"))]
        .iter()
        .filter(|&&(_, on)| on)
        .map(|&(name, _)| Value::Str(name.to_string()))
        .collect();
    Value::obj(vec![
        ("git_commit", Value::Str(tool_line("git", &["rev-parse", "HEAD"]))),
        ("rustc", Value::Str(tool_line("rustc", &["--version"]))),
        ("features", Value::Arr(features)),
    ])
}

/// A bench run's structured record: sections of [`BenchResult`]s,
/// optionally written to `BENCH_<name>.json` when the target was invoked
/// with `--json` (`cargo bench --bench <name> -- --json`).
pub struct BenchLog {
    name: String,
    json: bool,
    sections: Vec<(String, Vec<BenchResult>)>,
}

impl BenchLog {
    /// A log for bench target `name`; JSON output is enabled when the
    /// process arguments contain `--json`.
    pub fn from_env(name: &str) -> BenchLog {
        BenchLog {
            name: name.to_string(),
            json: std::env::args().any(|a| a == "--json"),
            sections: Vec::new(),
        }
    }

    /// Print a section header and open a new result group.
    pub fn section(&mut self, title: &str) {
        section(title);
        self.sections.push((title.to_string(), Vec::new()));
    }

    /// Run [`bench`] and record the result under the current section.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        let r = bench_cfg(name, 20, 0.25, &mut f);
        self.push(r.clone());
        r
    }

    /// Record a single-point measurement (ns) under the current section.
    pub fn note(&mut self, name: &str, ns: f64) {
        self.push(BenchResult::point(name, ns));
    }

    fn push(&mut self, r: BenchResult) {
        if self.sections.is_empty() {
            self.sections.push((String::new(), Vec::new()));
        }
        self.sections.last_mut().unwrap().1.push(r);
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("bench", Value::Str(self.name.clone())),
            ("meta", meta_json()),
            (
                "sections",
                Value::Arr(
                    self.sections
                        .iter()
                        .map(|(title, results)| {
                            Value::obj(vec![
                                ("title", Value::Str(title.clone())),
                                (
                                    "results",
                                    Value::Arr(results.iter().map(|r| r.to_json()).collect()),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// When `--json` was passed, write `BENCH_<name>.json` (pretty JSON)
    /// into the working directory and return the path.
    pub fn finish(&self) -> std::io::Result<Option<String>> {
        if !self.json {
            return Ok(None);
        }
        let path = format!("BENCH_{}.json", self.name);
        std::fs::write(&path, self.to_json().pretty())?;
        println!("\nwrote {path}");
        Ok(Some(path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_cfg("spin", 5, 0.0, &mut || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 5);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.500 us");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }

    #[test]
    fn bench_log_collects_sections_into_json() {
        let mut log = BenchLog {
            name: "unit".into(),
            json: false,
            sections: Vec::new(),
        };
        log.section("alpha");
        log.note("point metric", 1234.5);
        log.bench("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        let doc = log.to_json();
        assert_eq!(doc.get("bench").and_then(|v| v.as_str()), Some("unit"));
        // provenance header: always present, never empty strings
        let meta = doc.get("meta").expect("meta header");
        for key in ["git_commit", "rustc"] {
            let s = meta.get(key).and_then(|v| v.as_str()).unwrap();
            assert!(!s.is_empty(), "{key} must be a value or \"unknown\"");
        }
        assert!(meta.get("features").and_then(|v| v.as_arr()).is_some());
        let sections = doc.get("sections").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(sections.len(), 1);
        let results = sections[0].get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("median_ns").and_then(|v| v.as_f64()),
            Some(1234.5)
        );
        // json=false: finish writes nothing
        assert_eq!(log.finish().unwrap(), None);
    }
}
