//! Minimal JSON: parse + pretty-print.
//!
//! Used for the artifact manifest (the Python<->Rust contract), SoC config
//! files, and `--json` CLI output. Supports the full JSON grammar except
//! exotic number forms (parses them as f64, like most implementations);
//! objects preserve insertion order so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|n| usize::try_from(n).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    // -- printing ------------------------------------------------------------

    /// Compact form.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty form (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    anyhow::ensure!(p.i == p.b.len(), "trailing characters at byte {}", p.i);
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> anyhow::Result<Value> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(s.as_bytes()),
            "bad literal at byte {}",
            self.i
        );
        self.i += s.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => anyhow::bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            anyhow::ensure!(self.i + 4 <= self.b.len(), "short \\u escape");
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape at {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the full sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "hi\n\"x\""}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi\n\"x\""));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "artifacts": {
            "firenet": {
              "engine": "sne",
              "inputs": [{"name": "events", "shape": [2, 64, 64], "dtype": "f32"}],
              "sha256": "ab"
            }
          },
          "seed": 12648430
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(12648430));
        let art = v.get("artifacts").unwrap().get("firenet").unwrap();
        assert_eq!(art.get("engine").unwrap().as_str(), Some("sne"));
        let shape = art.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap();
        let dims: Vec<u64> = shape.as_arr().unwrap().iter().map(|d| d.as_u64().unwrap()).collect();
        assert_eq!(dims, vec![2, 64, 64]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{\"a\": 1} trailing").is_err());
    }

    #[test]
    fn integer_printing_is_exact() {
        let v = Value::Num(12648430.0);
        assert_eq!(v.to_string(), "12648430");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn float_printing_roundtrips_bitwise() {
        // The serve wire protocol and its result cache rely on this:
        // printing any finite f64 and parsing it back must reproduce the
        // exact bit pattern (Rust float formatting is shortest-roundtrip).
        for x in [
            0.1,
            1.0 / 3.0,
            2.0f64.powi(-40),
            9.87654321e-12,
            0.098_000_000_000_000_04, // accumulated-sum style residue
            1e300,
            -2.5e-300,
            123456789.123456789,
        ] {
            let v = Value::Num(x);
            let re = parse(&v.to_string()).unwrap();
            assert_eq!(
                re.as_f64().unwrap().to_bits(),
                x.to_bits(),
                "float {x} drifted through print/parse"
            );
        }
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Value::Num(4.0).as_usize(), Some(4));
        assert_eq!(Value::Num(4.5).as_usize(), None);
        assert_eq!(Value::Num(-1.0).as_usize(), None);
        assert_eq!(Value::Str("4".into()).as_usize(), None);
    }
}
