//! Frame camera simulator (HM01B0-class BW imager).
//!
//! Global-shutter grayscale sensor with configurable resolution and frame
//! rate. Provides the preprocessing the FC firmware performs before
//! dispatching frames to the engines: center-crop + box-downsample to the
//! network input resolution, mean-centering to the int8 range (DroNet) or
//! ternarization (CUTIE).

use crate::sensors::scene::Scene;

/// Frame sensor + FC-side preprocessing.
#[derive(Debug, Clone)]
pub struct FrameSensor {
    pub width: usize,
    pub height: usize,
    pub fps: f64,
    frame_idx: u64,
}

impl FrameSensor {
    pub fn new(width: usize, height: usize, fps: f64) -> Self {
        FrameSensor { width, height, fps, frame_idx: 0 }
    }

    /// Timestamp (ns) of the next frame.
    pub fn next_frame_t_ns(&self) -> u64 {
        (self.frame_idx as f64 / self.fps * 1e9) as u64
    }

    /// Capture the next frame in sequence; returns (t_ns, pixels in [0,1]).
    pub fn capture(&mut self, scene: &mut Scene) -> (u64, Vec<f32>) {
        let t_ns = self.tick(scene);
        let img = scene.render(self.width, self.height, t_ns as f64 * 1e-9);
        (t_ns, img)
    }

    /// Advance to the next frame instant *without* rendering pixels:
    /// the scene state still moves (obstacle re-rolls, ego-motion) exactly
    /// as under [`FrameSensor::capture`], so analytical missions — whose
    /// reports never read frame pixels — and trace capture can skip the
    /// render entirely. Returns the frame timestamp (ns).
    pub fn tick(&mut self, scene: &mut Scene) -> u64 {
        let t_ns = self.next_frame_t_ns();
        scene.advance(t_ns as f64 * 1e-9);
        self.frame_idx += 1;
        t_ns
    }

    /// Bytes per raw frame (8-bit luma) — DMA sizing for the CPI peripheral.
    pub fn frame_bytes(&self) -> usize {
        self.width * self.height
    }
}

/// Center-crop to square then box-downsample to `out` x `out`.
///
/// Row-hoisted like the scene renderers: the source-row span is constant
/// across an output row and the source-column spans depend only on the
/// output column, so both are computed once instead of per output pixel,
/// and the box sum walks contiguous source-row slices (hotpath §4). The
/// summation order (source rows ascending, columns ascending) is
/// unchanged, so results stay bit-identical to the per-pixel form.
pub fn downsample_square(img: &[f32], w: usize, h: usize, out: usize) -> Vec<f32> {
    assert_eq!(img.len(), w * h);
    let side = w.min(h);
    let x0 = (w - side) / 2;
    let y0 = (h - side) / 2;
    let mut res = vec![0f32; out * out];
    let scale = side as f64 / out as f64;
    let xspan: Vec<(usize, usize)> = (0..out)
        .map(|ox| {
            let sx0 = x0 + (ox as f64 * scale) as usize;
            let sx1 = (x0 + ((ox + 1) as f64 * scale).ceil() as usize).min(x0 + side);
            (sx0, sx1.max(sx0 + 1))
        })
        .collect();
    for (oy, orow) in res.chunks_exact_mut(out).enumerate() {
        let sy0 = y0 + (oy as f64 * scale) as usize;
        let sy1 = (y0 + ((oy + 1) as f64 * scale).ceil() as usize).min(y0 + side);
        let sy1 = sy1.max(sy0 + 1);
        for (px, &(sx0, sx1)) in orow.iter_mut().zip(&xspan) {
            // box filter over the source rectangle of this output pixel
            let mut sum = 0f64;
            let mut n = 0usize;
            for yy in sy0..sy1 {
                for &v in &img[yy * w + sx0..yy * w + sx1] {
                    sum += v as f64;
                    n += 1;
                }
            }
            *px = (sum / n as f64) as f32;
        }
    }
    res
}

/// Mean-center and scale to the int8 range (DroNet input convention;
/// values are exact integers carried in f32 — see python/compile).
pub fn to_int8_luma(img: &[f32]) -> Vec<f32> {
    let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
    img.iter()
        .map(|&v| (((v - mean) * 255.0).round()).clamp(-128.0, 127.0))
        .collect()
}

/// Ternarize a (single-channel) image to {-1, 0, +1} around its mean and
/// replicate to `ch` channels (CUTIE input convention).
pub fn to_ternary(img: &[f32], ch: usize, thr: f32) -> Vec<f32> {
    let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
    let one: Vec<f32> = img
        .iter()
        .map(|&v| {
            let d = v - mean;
            if d > thr {
                1.0
            } else if d < -thr {
                -1.0
            } else {
                0.0
            }
        })
        .collect();
    let mut out = Vec::with_capacity(ch * one.len());
    for _ in 0..ch {
        out.extend_from_slice(&one);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensors::scene::{Scene, SceneKind};

    #[test]
    fn frame_cadence() {
        let mut cam = FrameSensor::new(64, 48, 30.0);
        let mut scene = Scene::new(SceneKind::Corridor { speed_per_s: 0.5, seed: 1 });
        let (t0, _) = cam.capture(&mut scene);
        let (t1, _) = cam.capture(&mut scene);
        assert_eq!(t0, 0);
        assert!((t1 as f64 - 1e9 / 30.0).abs() < 1.0);
    }

    #[test]
    fn downsample_preserves_mean_roughly() {
        let img: Vec<f32> = (0..320 * 240).map(|i| (i % 7) as f32 / 7.0).collect();
        let small = downsample_square(&img, 320, 240, 96);
        assert_eq!(small.len(), 96 * 96);
        let m_in: f32 = img.iter().sum::<f32>() / img.len() as f32;
        let m_out: f32 = small.iter().sum::<f32>() / small.len() as f32;
        assert!((m_in - m_out).abs() < 0.1);
    }

    #[test]
    fn downsample_identity_size() {
        let img: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let out = downsample_square(&img, 4, 4, 4);
        assert_eq!(out, img);
    }

    #[test]
    fn int8_luma_range_and_integer() {
        let img: Vec<f32> = (0..96 * 96).map(|i| ((i % 251) as f32) / 250.0).collect();
        let q = to_int8_luma(&img);
        for &v in &q {
            assert!((-128.0..=127.0).contains(&v));
            assert_eq!(v, v.round());
        }
    }

    #[test]
    fn ternary_replicates_channels() {
        let img = vec![0.0f32, 0.5, 1.0, 0.5];
        let t = to_ternary(&img, 3, 0.2);
        assert_eq!(t.len(), 12);
        assert_eq!(&t[0..4], &t[4..8]);
        assert!(t.iter().all(|&v| v == -1.0 || v == 0.0 || v == 1.0));
        assert_eq!(t[0], -1.0);
        assert_eq!(t[2], 1.0);
    }
}
