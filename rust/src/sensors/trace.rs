//! Sensor traces: capture a mission's full sensor input once, replay it
//! everywhere.
//!
//! Profiling (`cargo bench --bench hotpath`) shows the mission loop is
//! dominated by the sensor front end — the per-sample scene render plus
//! the DVS pixel model at kHz rates. Yet for every grid/fleet cell that
//! differs only in SoC-side axes (vdd, gating policy) the generated
//! event/frame streams are *bit-identical*. The paper's own split —
//! sensors produce streams, the SoC consumes them — and follow-on
//! platforms that record event streams once and replay them against
//! different processing configurations (ColibriUAV) both argue for
//! decoupling stream generation from SoC evaluation. This module is that
//! decoupling:
//!
//! * a [`TraceKey`] names everything the sensor front end depends on —
//!   `(scene, seed, width x height, dvs_sample_hz, frame_fps, duration,
//!   window_ms)` — and nothing it does not (vdd, gating, telemetry are
//!   SoC-side). Two mission/stream configs with equal keys see
//!   bit-identical sensor input;
//! * a [`SensorTrace`] is the captured input: every inference window's
//!   DVS event stream in **one flat buffer** with window offset indices
//!   (no per-window `Vec` allocations) plus the frame timestamps and
//!   ground-truth labels. Traces carry no frame *pixels*, so replay is
//!   analytical-only — artifact-backed (functional) missions sense live;
//! * an [`EventSource`] is what the mission/workload pipelines actually
//!   hold: `Live` (scene + DVS + frame camera, sensing on demand) or
//!   `Replay` (an `Arc<SensorTrace>` shared freely across cells and
//!   worker threads). A replayed run is bit-identical to a live one —
//!   `tests/integration_trace.rs` pins the whole report, snapshots
//!   included, for every [`SceneKind`].
//!
//! Capture replicates the mission DES's sensor-visible event order
//! exactly (at equal timestamps a window opens before a frame lands), so
//! the scene's stochastic state — corridor obstacle re-rolls happen in
//! `Scene::advance` — evolves identically under capture and live runs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::event::{Event, EventWindow};
use crate::sensors::scene::{Scene, SceneKind};
use crate::sensors::{DvsSim, FrameSensor, DVS_HEIGHT, DVS_WIDTH, FRAME_HEIGHT, FRAME_WIDTH};
use crate::store::{MappedTrace, Store};
use crate::util::fnv1a;

/// Everything the sensor front end of a mission/stream depends on. Two
/// configs with equal keys (canonical-string equality: every float
/// compared bit for bit via its shortest-roundtrip `Debug` form, the
/// result-cache discipline) produce bit-identical sensor streams.
#[derive(Debug, Clone)]
pub struct TraceKey {
    pub scene: SceneKind,
    /// DVS noise seed (and the scene seed, where the scene carries one —
    /// the mission seed discipline keeps them equal).
    pub seed: u64,
    /// DVS geometry.
    pub width: usize,
    pub height: usize,
    /// DVS sampling rate inside a window (Hz).
    pub dvs_sample_hz: f64,
    pub frame_fps: f64,
    pub duration_s: f64,
    /// Inference-window length (ms): it shapes the per-window sample
    /// instants, so it is part of the stream, not of the SoC.
    pub window_ms: f64,
}

impl TraceKey {
    /// The canonical string two keys are compared by (and hashed from).
    pub fn canonical(&self) -> String {
        format!(
            "trace|{:?}|{}|{}x{}|hz={:?}|fps={:?}|dur={:?}|win={:?}",
            self.scene,
            self.seed,
            self.width,
            self.height,
            self.dvs_sample_hz,
            self.frame_fps,
            self.duration_s,
            self.window_ms
        )
    }

    /// 64-bit FNV-1a of the canonical string (cache indexing).
    pub fn fnv64(&self) -> u64 {
        fnv1a(self.canonical().as_bytes())
    }
}

/// One captured frame instant: its timestamp and the scene ground truth
/// the analytical PULP path consumes. No pixels — see module docs.
#[derive(Debug, Clone, Copy)]
pub struct FrameRecord {
    pub t_ns: u64,
    pub steer: f64,
    pub collision: bool,
}

/// A captured sensor input: per-window DVS event streams in one flat
/// buffer with window offsets, plus the frame records.
#[derive(Debug, Clone)]
pub struct SensorTrace {
    pub key: TraceKey,
    /// Frame-camera geometry (constant today, recorded for honesty).
    pub frame_w: usize,
    pub frame_h: usize,
    /// All events of the whole mission, window-major, time-sorted.
    events: Vec<Event>,
    /// `offsets[w]..offsets[w + 1]` slices window `w` out of `events`.
    offsets: Vec<usize>,
    frames: Vec<FrameRecord>,
}

impl SensorTrace {
    /// Run the sensor front end over the whole mission duration once,
    /// recording every window's events and every frame's timestamp/truth.
    /// The loop replicates the mission DES's sensor event order: windows
    /// fire at `w * window_ns`, frames at the camera cadence, and at
    /// equal timestamps the window opens first (the scheduler tie-break).
    ///
    /// Capture rides the vectorized DVS step (`sensors::dvs`), which is
    /// bit-identical to the scalar reference —
    /// [`SensorTrace::capture_scalar_reference`] runs the *same* loop
    /// over the scalar step so `tests/integration_trace.rs` can pin the
    /// whole trace (windows + frames) against it for every [`SceneKind`].
    pub fn capture(key: &TraceKey) -> SensorTrace {
        Self::capture_with(key, |dvs, scene, ts, win| dvs.step_into(scene, ts, win))
    }

    /// The scalar-reference twin of [`SensorTrace::capture`]: identical
    /// capture loop, scalar DVS step. Kept behind the default-on
    /// `scalar-ref` feature purely as the bit-identity anchor of the
    /// vectorized front end.
    #[cfg(any(test, feature = "scalar-ref"))]
    pub fn capture_scalar_reference(key: &TraceKey) -> SensorTrace {
        Self::capture_with(key, |dvs, scene, ts, win| dvs.step_into_scalar(scene, ts, win))
    }

    /// The one capture loop both entry points share, parameterized over
    /// the DVS step so the vectorized and scalar-reference captures
    /// cannot drift in frame interleaving or window sampling.
    fn capture_with(
        key: &TraceKey,
        mut step: impl FnMut(&mut DvsSim, &Scene, u64, &mut EventWindow),
    ) -> SensorTrace {
        let window_ns = (key.window_ms * 1e6) as u64;
        let n_windows = (key.duration_s * 1e9 / window_ns as f64) as u64;
        let end_ns = n_windows * window_ns;

        let mut dvs = DvsSim::new(key.width, key.height, key.seed);
        let mut cam = FrameSensor::new(FRAME_WIDTH, FRAME_HEIGHT, key.frame_fps);
        let mut scene = Scene::new(key.scene);
        let mut win = EventWindow::new(key.width, key.height);
        let mut events: Vec<Event> = Vec::new();
        let mut offsets = Vec::with_capacity(n_windows as usize + 1);
        offsets.push(0);
        let mut frames: Vec<FrameRecord> = Vec::new();

        fn grab_frame(cam: &mut FrameSensor, scene: &mut Scene, frames: &mut Vec<FrameRecord>) {
            let t_ns = cam.tick(scene);
            let (steer, collision) = scene.corridor_truth(t_ns as f64 * 1e-9);
            frames.push(FrameRecord { t_ns, steer, collision });
        }

        // the first frame is scheduled unconditionally (mission run loop)
        let mut next_frame = if n_windows > 0 { cam.next_frame_t_ns() } else { u64::MAX };
        // per-window sample count is invariant across windows: hoist it
        let n_samples = ((window_ns as f64 * 1e-9) * key.dvs_sample_hz).max(1.0) as u64;
        for w in 0..n_windows {
            let t0 = w * window_ns;
            while next_frame < t0 {
                grab_frame(&mut cam, &mut scene, &mut frames);
                let t = cam.next_frame_t_ns();
                next_frame = if t < end_ns { t } else { u64::MAX };
            }
            win.events.clear();
            for k in 0..=n_samples {
                let ts = t0 + k * window_ns / (n_samples + 1);
                scene.advance(ts as f64 * 1e-9);
                step(&mut dvs, &scene, ts, &mut win);
            }
            events.extend_from_slice(&win.events);
            offsets.push(events.len());
        }
        while next_frame < end_ns {
            grab_frame(&mut cam, &mut scene, &mut frames);
            let t = cam.next_frame_t_ns();
            next_frame = if t < end_ns { t } else { u64::MAX };
        }

        SensorTrace {
            key: key.clone(),
            frame_w: FRAME_WIDTH,
            frame_h: FRAME_HEIGHT,
            events,
            offsets,
            frames,
        }
    }

    /// Inference windows captured.
    pub fn n_windows(&self) -> u64 {
        (self.offsets.len() - 1) as u64
    }

    /// The event stream of window `w`.
    pub fn window(&self, w: u64) -> &[Event] {
        let w = w as usize;
        &self.events[self.offsets[w]..self.offsets[w + 1]]
    }

    /// Total events across all windows.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Frame records, in capture order.
    pub fn frames(&self) -> &[FrameRecord] {
        &self.frames
    }

    /// Approximate resident size (bytes) — what the serve trace cache
    /// reports so operators can size `--trace-cache`.
    pub fn approx_bytes(&self) -> usize {
        self.events.len() * std::mem::size_of::<Event>()
            + self.offsets.len() * std::mem::size_of::<usize>()
            + self.frames.len() * std::mem::size_of::<FrameRecord>()
    }

    /// The flat event buffer and its window-offset index — what the
    /// store serializer (`crate::store::format`) writes out.
    pub(crate) fn raw_events(&self) -> (&[Event], &[usize]) {
        (&self.events, &self.offsets)
    }

    /// Reassemble a trace from its serialized parts (the store decode
    /// path). Private shape invariants (window-major flat buffer,
    /// `offsets[0] == 0`, `offsets.last() == events.len()`) are the
    /// writer's responsibility; `crate::store::format::parse_trace`
    /// verifies them before this is reachable.
    pub(crate) fn from_parts(
        key: TraceKey,
        frame_w: usize,
        frame_h: usize,
        events: Vec<Event>,
        offsets: Vec<usize>,
        frames: Vec<FrameRecord>,
    ) -> SensorTrace {
        SensorTrace { key, frame_w, frame_h, events, offsets, frames }
    }
}

/// A shareable, replayable sensor trace in either tier: resident
/// ([`SensorTrace`], the memory tier / fresh captures) or mapped from a
/// store file ([`MappedTrace`], the disk tier — events stay on disk and
/// stream per window). Both replay bit-identically to live sensing; the
/// serve cache and the pool pass these around so a disk-tier hit never
/// forces a wholesale decode.
#[derive(Debug, Clone)]
pub enum TraceHandle {
    Mem(Arc<SensorTrace>),
    Mapped(Arc<MappedTrace>),
}

impl TraceHandle {
    pub fn key(&self) -> &TraceKey {
        match self {
            TraceHandle::Mem(t) => &t.key,
            TraceHandle::Mapped(m) => m.key(),
        }
    }

    /// Build the replay [`EventSource`] for a consumer expecting `want`
    /// (canonical-key validated, like [`EventSource::replay_for`]).
    pub fn source_for(&self, want: &TraceKey) -> crate::Result<EventSource> {
        match self {
            TraceHandle::Mem(t) => EventSource::replay_for(Arc::clone(t), want),
            TraceHandle::Mapped(m) => EventSource::mapped_for(Arc::clone(m), want),
        }
    }

    /// Resident bytes of this entry (memory-tier accounting): the full
    /// buffers for `Mem`, just the decoded index for `Mapped`.
    pub fn mem_bytes(&self) -> usize {
        match self {
            TraceHandle::Mem(t) => t.approx_bytes(),
            TraceHandle::Mapped(m) => m.resident_bytes(),
        }
    }

    /// Bytes this entry keeps on disk (disk-tier accounting): the store
    /// file size for `Mapped`, zero for `Mem`.
    pub fn disk_bytes(&self) -> usize {
        match self {
            TraceHandle::Mem(_) => 0,
            TraceHandle::Mapped(m) => m.file_bytes(),
        }
    }
}

/// Where a pipeline's sensor input comes from: a live simulated front end
/// (boxed — it carries the whole pixel-array state), a prerecorded
/// in-memory trace shared via `Arc`, or a store file mapped read-only
/// (events decoded per window straight off the mapping — the whole
/// corpus is never deserialized).
#[derive(Debug, Clone)]
pub enum EventSource {
    Live(Box<LiveSensors>),
    Replay(TraceCursor),
    Mapped(MappedCursor),
}

/// The live front end: scene + DVS + frame camera, plus one reusable
/// event-window staging buffer (no per-window allocation).
#[derive(Debug, Clone)]
pub struct LiveSensors {
    dvs: DvsSim,
    cam: FrameSensor,
    scene: Scene,
    win: EventWindow,
}

/// Replay position inside a shared trace.
#[derive(Debug, Clone)]
pub struct TraceCursor {
    trace: Arc<SensorTrace>,
    frame_idx: usize,
}

/// Replay position inside a mapped store file, plus one reusable staging
/// buffer the current window is decoded into (per-window decode is the
/// only per-replay allocation; the events themselves stay on disk).
#[derive(Debug, Clone)]
pub struct MappedCursor {
    map: Arc<MappedTrace>,
    frame_idx: usize,
    staging: Vec<Event>,
}

impl EventSource {
    /// A live source at the standard testbed geometry (DVS132S + HM01B0).
    pub fn live(seed: u64, frame_fps: f64, scene: SceneKind) -> EventSource {
        EventSource::Live(Box::new(LiveSensors {
            dvs: DvsSim::new(DVS_WIDTH, DVS_HEIGHT, seed),
            cam: FrameSensor::new(FRAME_WIDTH, FRAME_HEIGHT, frame_fps),
            scene: Scene::new(scene),
            win: EventWindow::new(DVS_WIDTH, DVS_HEIGHT),
        }))
    }

    /// A replay source over `trace`, validated against the key the
    /// consuming mission/stream expects — a mismatched trace is a config
    /// error, never a silently different stream.
    pub fn replay_for(trace: Arc<SensorTrace>, want: &TraceKey) -> crate::Result<EventSource> {
        anyhow::ensure!(
            trace.key.canonical() == want.canonical(),
            "sensor trace key mismatch:\n  trace:  {}\n  wanted: {}",
            trace.key.canonical(),
            want.canonical()
        );
        Ok(EventSource::Replay(TraceCursor { trace, frame_idx: 0 }))
    }

    /// A replay source streaming from a verified store mapping —
    /// key-validated exactly like [`EventSource::replay_for`].
    pub fn mapped_for(map: Arc<MappedTrace>, want: &TraceKey) -> crate::Result<EventSource> {
        anyhow::ensure!(
            map.key().canonical() == want.canonical(),
            "sensor trace key mismatch:\n  trace:  {}\n  wanted: {}",
            map.key().canonical(),
            want.canonical()
        );
        Ok(EventSource::Mapped(MappedCursor { map, frame_idx: 0, staging: Vec::new() }))
    }

    pub fn is_replay(&self) -> bool {
        !matches!(self, EventSource::Live(_))
    }

    /// DVS geometry (width, height).
    pub fn dims(&self) -> (usize, usize) {
        match self {
            EventSource::Live(l) => (l.dvs.width, l.dvs.height),
            EventSource::Replay(r) => (r.trace.key.width, r.trace.key.height),
            EventSource::Mapped(m) => (m.map.key().width, m.map.key().height),
        }
    }

    /// Frame-camera geometry (width, height).
    pub fn frame_dims(&self) -> (usize, usize) {
        match self {
            EventSource::Live(l) => (l.cam.width, l.cam.height),
            EventSource::Replay(r) => (r.trace.frame_w, r.trace.frame_h),
            EventSource::Mapped(m) => m.map.frame_dims(),
        }
    }

    /// Bytes per raw frame (8-bit luma) — CPI DMA sizing.
    pub fn frame_bytes(&self) -> usize {
        let (w, h) = self.frame_dims();
        w * h
    }

    /// Timestamp (ns) of the next frame. Replay reads the *recorded*
    /// timestamps, so it stays bit-identical to capture even if the
    /// camera's cadence model ever changes; past the last recorded frame
    /// it reports `u64::MAX`, which the mission's `next < end_ns` guard
    /// never schedules.
    pub fn next_frame_t_ns(&self) -> u64 {
        match self {
            EventSource::Live(l) => l.cam.next_frame_t_ns(),
            EventSource::Replay(r) => {
                r.trace.frames.get(r.frame_idx).map_or(u64::MAX, |f| f.t_ns)
            }
            EventSource::Mapped(m) => {
                m.map.frames().get(m.frame_idx).map_or(u64::MAX, |f| f.t_ns)
            }
        }
    }

    /// The DVS event stream of inference window `w` (`[t0, t0 +
    /// window_ns)` sampled at `sample_hz`): live sources sense it, replay
    /// sources hand back the captured slice without touching a pixel, and
    /// mapped sources decode exactly window `w` off the store file into
    /// the cursor's staging buffer.
    pub fn window_events(&mut self, w: u64, t0: u64, window_ns: u64, sample_hz: f64) -> &[Event] {
        match self {
            EventSource::Live(l) => l.sense_window(t0, window_ns, sample_hz),
            EventSource::Replay(r) => r.trace.window(w),
            EventSource::Mapped(m) => {
                m.map.window_into(w, &mut m.staging);
                &m.staging
            }
        }
    }

    /// Advance to the next frame: its timestamp, the rendered image when
    /// `need_img` (live only — traces carry no pixels and must not be
    /// paired with the functional runtime), and the scene ground truth
    /// (steer, collision) at the frame instant.
    pub fn capture_frame(&mut self, need_img: bool) -> (u64, Option<Vec<f32>>, (f64, bool)) {
        match self {
            EventSource::Live(l) => {
                let (t_ns, img) = if need_img {
                    let (t, img) = l.cam.capture(&mut l.scene);
                    (t, Some(img))
                } else {
                    (l.cam.tick(&mut l.scene), None)
                };
                let truth = l.scene.corridor_truth(t_ns as f64 * 1e-9);
                (t_ns, img, truth)
            }
            EventSource::Replay(r) => {
                assert!(!need_img, "trace replay carries no frame pixels");
                let f = r.trace.frames[r.frame_idx];
                r.frame_idx += 1;
                (f.t_ns, None, (f.steer, f.collision))
            }
            EventSource::Mapped(m) => {
                assert!(!need_img, "trace replay carries no frame pixels");
                let f = m.map.frames()[m.frame_idx];
                m.frame_idx += 1;
                (f.t_ns, None, (f.steer, f.collision))
            }
        }
    }
}

impl LiveSensors {
    fn sense_window(&mut self, t0: u64, window_ns: u64, sample_hz: f64) -> &[Event] {
        self.win.events.clear();
        let n_samples = ((window_ns as f64 * 1e-9) * sample_hz).max(1.0) as u64;
        for k in 0..=n_samples {
            let ts = t0 + k * window_ns / (n_samples + 1);
            self.scene.advance(ts as f64 * 1e-9);
            self.dvs.step_into(&self.scene, ts, &mut self.win);
        }
        &self.win.events
    }
}

/// Capture each *distinct* key once — in parallel over up to `threads`
/// scoped threads — and hand every input position an `Arc` of its trace.
/// Duplicate keys share one capture and one allocation.
pub fn capture_all(keys: &[TraceKey], threads: usize) -> Vec<Arc<SensorTrace>> {
    let mut slot_of: HashMap<String, usize> = HashMap::new();
    let mut distinct: Vec<TraceKey> = Vec::new();
    let mut slots: Vec<usize> = Vec::with_capacity(keys.len());
    for k in keys {
        let canon = k.canonical();
        let next_slot = distinct.len();
        let slot = *slot_of.entry(canon).or_insert_with(|| {
            distinct.push(k.clone());
            next_slot
        });
        slots.push(slot);
    }
    let threads = threads.clamp(1, distinct.len().max(1));
    let next = AtomicUsize::new(0);
    let captured: Vec<Mutex<Option<Arc<SensorTrace>>>> =
        (0..distinct.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= distinct.len() {
                    break;
                }
                *captured[i].lock().unwrap() = Some(Arc::new(SensorTrace::capture(&distinct[i])));
            });
        }
    });
    let captured: Vec<Arc<SensorTrace>> = captured
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("trace captured"))
        .collect();
    slots.into_iter().map(|s| Arc::clone(&captured[s])).collect()
}

/// The offline fleet/grid sharing policy: positions whose key repeats
/// share one captured trace; unique keys (and `None` positions — e.g.
/// artifact-backed configs) stay live, where capture-then-replay would
/// only add memory for no sensing win.
pub fn shared_traces(keys: &[Option<TraceKey>], threads: usize) -> Vec<Option<Arc<SensorTrace>>> {
    let mut count: HashMap<String, usize> = HashMap::new();
    for k in keys.iter().flatten() {
        *count.entry(k.canonical()).or_insert(0) += 1;
    }
    let mut idx: Vec<usize> = Vec::new();
    let mut repeated: Vec<TraceKey> = Vec::new();
    for (i, k) in keys.iter().enumerate() {
        if let Some(k) = k {
            if count[&k.canonical()] > 1 {
                idx.push(i);
                repeated.push(k.clone());
            }
        }
    }
    let mut out: Vec<Option<Arc<SensorTrace>>> = vec![None; keys.len()];
    for (i, t) in idx.into_iter().zip(capture_all(&repeated, threads)) {
        out[i] = Some(t);
    }
    out
}

/// The store-aware sharing policy — [`shared_traces`] generalized over a
/// corpus directory. Without a store it is exactly [`shared_traces`]
/// (only repeated keys shared). With one, capture-once becomes
/// **capture-once-ever**: *every* shareable key first consults the store
/// (a hit replays via mmap — [`TraceHandle::Mapped`] — without decoding
/// the corpus), and the distinct keys the store doesn't have yet are
/// captured once and persisted, so the next process pays nothing.
/// Store I/O is best-effort: a write failure logs and degrades to the
/// in-memory handle, never fails the run.
pub fn shared_handles(
    keys: &[Option<TraceKey>],
    threads: usize,
    store: Option<&Store>,
) -> Vec<Option<TraceHandle>> {
    let Some(store) = store else {
        return shared_traces(keys, threads)
            .into_iter()
            .map(|t| t.map(TraceHandle::Mem))
            .collect();
    };
    // disk tier first: one open per *distinct* key
    let mut by_canon: HashMap<String, Option<TraceHandle>> = HashMap::new();
    let mut to_capture: Vec<TraceKey> = Vec::new();
    for k in keys.iter().flatten() {
        let canon = k.canonical();
        if by_canon.contains_key(&canon) {
            continue;
        }
        let hit = store.load_trace(k).map(TraceHandle::Mapped);
        if hit.is_none() {
            to_capture.push(k.clone());
        }
        by_canon.insert(canon, hit);
    }
    for (k, t) in to_capture.iter().zip(capture_all(&to_capture, threads)) {
        if let Err(e) = store.save_trace(&t) {
            eprintln!("store: could not persist {}: {e:#}", k.canonical());
        }
        by_canon.insert(k.canonical(), Some(TraceHandle::Mem(t)));
    }
    keys.iter()
        .map(|k| k.as_ref().and_then(|k| by_canon[&k.canonical()].clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(seed: u64) -> TraceKey {
        TraceKey {
            scene: SceneKind::Corridor { speed_per_s: 0.5, seed },
            seed,
            width: DVS_WIDTH,
            height: DVS_HEIGHT,
            dvs_sample_hz: 300.0,
            frame_fps: 30.0,
            duration_s: 0.2,
            window_ms: 10.0,
        }
    }

    #[test]
    fn capture_is_deterministic_and_windowed() {
        let a = SensorTrace::capture(&key(3));
        let b = SensorTrace::capture(&key(3));
        assert_eq!(a.n_windows(), 20);
        assert_eq!(a.len(), b.len());
        for w in 0..a.n_windows() {
            assert_eq!(a.window(w), b.window(w), "window {w}");
        }
        assert_eq!(a.frames().len(), b.frames().len());
        // 0.2 s at 30 fps: frames 0..=5 fall inside [0, 0.2 s)
        assert_eq!(a.frames().len(), 6);
        assert!(a.approx_bytes() > 0);
    }

    #[test]
    fn windows_concatenate_to_the_flat_buffer() {
        let t = SensorTrace::capture(&key(5));
        let total: usize = (0..t.n_windows()).map(|w| t.window(w).len()).sum();
        assert_eq!(total, t.len());
        assert!(!t.is_empty(), "corridor at 300 Hz must produce events");
    }

    #[test]
    fn canonical_key_separates_sensor_axes_only() {
        let base = key(1);
        assert_eq!(base.canonical(), key(1).canonical());
        assert_eq!(base.fnv64(), key(1).fnv64());
        let mut hz = key(1);
        hz.dvs_sample_hz += 1.0;
        assert_ne!(base.canonical(), hz.canonical());
        let mut dur = key(1);
        dur.duration_s += 1e-9; // one ulp-scale change must change the key
        assert_ne!(base.canonical(), dur.canonical());
        assert_ne!(base.canonical(), key(2).canonical());
    }

    #[test]
    fn replay_source_hands_back_captured_windows() {
        let trace = Arc::new(SensorTrace::capture(&key(7)));
        let mut src = EventSource::replay_for(Arc::clone(&trace), &key(7)).unwrap();
        assert!(src.is_replay());
        assert_eq!(src.dims(), (DVS_WIDTH, DVS_HEIGHT));
        assert_eq!(src.frame_bytes(), FRAME_WIDTH * FRAME_HEIGHT);
        let evs = src.window_events(2, 2 * 10_000_000, 10_000_000, 300.0);
        assert_eq!(evs, trace.window(2));
        // frames replay in order with the recorded truths
        assert_eq!(src.next_frame_t_ns(), 0);
        let (t0, img, _) = src.capture_frame(false);
        assert_eq!(t0, trace.frames()[0].t_ns);
        assert!(img.is_none());
        assert_eq!(src.next_frame_t_ns(), (1f64 / 30.0 * 1e9) as u64);
    }

    #[test]
    fn mismatched_replay_key_is_rejected() {
        let trace = Arc::new(SensorTrace::capture(&key(7)));
        assert!(EventSource::replay_for(trace, &key(8)).is_err());
    }

    #[test]
    fn shared_traces_only_cover_repeated_keys() {
        let keys = vec![Some(key(1)), Some(key(2)), Some(key(1)), None, Some(key(1))];
        let out = shared_traces(&keys, 2);
        assert!(out[0].is_some() && out[2].is_some() && out[4].is_some());
        assert!(out[1].is_none(), "unique key stays live");
        assert!(out[3].is_none(), "ineligible position stays live");
        // repeated positions share the same allocation
        assert!(Arc::ptr_eq(out[0].as_ref().unwrap(), out[2].as_ref().unwrap()));
        assert!(Arc::ptr_eq(out[0].as_ref().unwrap(), out[4].as_ref().unwrap()));
    }

    #[test]
    fn capture_all_dedups_across_threads() {
        let keys = vec![key(1), key(2), key(1), key(2), key(1)];
        let out = capture_all(&keys, 4);
        assert_eq!(out.len(), 5);
        assert!(Arc::ptr_eq(&out[0], &out[2]));
        assert!(Arc::ptr_eq(&out[1], &out[3]));
        assert!(!Arc::ptr_eq(&out[0], &out[1]));
        // parallel capture matches serial capture
        let serial = SensorTrace::capture(&key(2));
        assert_eq!(out[1].len(), serial.len());
    }
}
