//! Simulated visual sensors and the synthetic world they observe.
//!
//! The paper's testbed pairs an IniVation DVS132S event camera with a Himax
//! HM01B0 320x240 BW imager on a nano-UAV. We cannot fly that rig, so
//! [`scene`] provides a procedural world (corridor flights, gestures,
//! moving targets) and [`dvs`]/[`frame`] implement the two sensor front-ends
//! over it: a log-intensity-change event camera with threshold, refractory
//! and background noise, and a global-shutter frame camera.
//!
//! The same generative models exist in `python/compile/data.py` so the
//! accuracy experiments and the Rust end-to-end driver see statistically
//! identical inputs.
//!
//! [`trace`] decouples stream generation from SoC evaluation: a
//! [`SensorTrace`] captures a mission's full sensor input once (flat
//! event buffer + frame records, keyed by [`TraceKey`]) and an
//! [`EventSource`] lets the coordinator consume either live sensors or a
//! shared replayed trace, bit-identically (DESIGN.md §9).
//!
//! The front end itself is vectorized (DESIGN.md §11): pixel state is
//! structure-of-arrays and the DVS band scan runs in [`DVS_LANES`]-wide
//! f32 lanes over the same row-contiguous buffers the per-kind scene
//! renderers emit, bit-identical to the retained scalar reference path.

pub mod dvs;
pub mod frame;
pub mod scene;
pub mod trace;

pub use dvs::{DvsSim, DVS_LANES};
pub use frame::FrameSensor;
pub use scene::{Scene, SceneKind};
pub use trace::{EventSource, SensorTrace, TraceKey};

/// DVS132S geometry as integrated on the Kraken testbed (paper §III).
pub const DVS_WIDTH: usize = 132;
pub const DVS_HEIGHT: usize = 128;

/// HM01B0 geometry.
pub const FRAME_WIDTH: usize = 320;
pub const FRAME_HEIGHT: usize = 240;
