//! Procedural scenes: the synthetic world both sensors observe.
//!
//! A [`Scene`] maps normalized coordinates and time to intensity in [0, 1].
//! Scenes are deterministic in their parameters so experiments replay
//! exactly; stochastic elements (obstacle placement) are seeded.

use crate::util::rng::Rng;

/// The shared row loop of the per-kind `render_into` specializations:
/// hands each row's normalized y and its contiguous row slice to
/// `per_row`, which emits the row in the same lane-contiguous layout the
/// vectorized DVS scan consumes (`sensors::dvs`). Keeping the row
/// coordinate arithmetic in one place pins every specialization to the
/// same normalization — and the same f32 bit patterns — so they can't
/// drift apart.
#[inline]
fn render_rows(img: &mut [f32], width: usize, height: usize, mut per_row: impl FnMut(f64, &mut [f32])) {
    if width == 0 {
        return;
    }
    let inv_h = 1.0 / height as f64;
    for (yy, row) in img.chunks_exact_mut(width).enumerate() {
        let y = (yy as f64 + 0.5) * inv_h - 0.5;
        per_row(y, row);
    }
}

/// The shared pixel loop: fill one row from a per-pixel intensity closure
/// over normalized x (the row-loop twin of [`render_rows`]).
#[inline]
fn fill_row(row: &mut [f32], inv_w: f64, mut px: impl FnMut(f64) -> f32) {
    for (xx, p) in row.iter_mut().enumerate() {
        let x = (xx as f64 + 0.5) * inv_w - 0.5;
        *p = px(x);
    }
}

/// Scene selector used by the CLI and the mission driver.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SceneKind {
    /// Bar rotating about the optical center (gesture-like; drives high,
    /// structured DVS activity).
    RotatingBar { omega_rad_s: f64 },
    /// Vertical edge translating horizontally (classic optical-flow probe).
    TranslatingEdge { vel_per_s: f64 },
    /// Ring expanding from the center (looming stimulus — collision cue).
    ExpandingRing { rate_per_s: f64 },
    /// Corridor flight: heading line + optional obstacle, with ego-motion.
    /// This is the Fig. 2 application scene.
    Corridor { speed_per_s: f64, seed: u64 },
    /// Spatio-temporal noise with tunable density — used to sweep DVS
    /// activity for Fig. 7 independent of scene structure.
    Noise { density: f64, seed: u64 },
}

impl SceneKind {
    /// Parse a CLI/protocol scene name into a `SceneKind` with canonical
    /// parameters, seeding the stochastic scenes with `seed`. This is the
    /// single name→scene mapping shared by `kraken run`/`fleet`, the grid
    /// axes, and the serve protocol.
    pub fn parse(name: &str, seed: u64) -> anyhow::Result<SceneKind> {
        Ok(match name {
            "corridor" => SceneKind::Corridor { speed_per_s: 0.5, seed },
            "bar" => SceneKind::RotatingBar { omega_rad_s: 6.0 },
            "edge" => SceneKind::TranslatingEdge { vel_per_s: 0.4 },
            "ring" => SceneKind::ExpandingRing { rate_per_s: 0.5 },
            "noise" => SceneKind::Noise { density: 0.05, seed },
            other => anyhow::bail!("unknown scene '{other}' (corridor|bar|edge|ring|noise)"),
        })
    }

    /// The canonical name `parse` accepts for this kind (grid-cell labels,
    /// protocol echoes).
    pub fn label(&self) -> &'static str {
        match self {
            SceneKind::RotatingBar { .. } => "bar",
            SceneKind::TranslatingEdge { .. } => "edge",
            SceneKind::ExpandingRing { .. } => "ring",
            SceneKind::Corridor { .. } => "corridor",
            SceneKind::Noise { .. } => "noise",
        }
    }
}

/// A procedural scene instance.
#[derive(Debug, Clone)]
pub struct Scene {
    pub kind: SceneKind,
    /// Obstacle state for Corridor (center x/y, half-size), regenerated as
    /// the UAV passes each obstacle.
    obstacle: (f64, f64, f64),
    steer: f64,
    last_lap: u64,
    rng: Rng,
}

impl Scene {
    pub fn new(kind: SceneKind) -> Self {
        let seed = match kind {
            SceneKind::Corridor { seed, .. } | SceneKind::Noise { seed, .. } => seed,
            _ => 0,
        };
        let mut rng = Rng::seed_from_u64(seed ^ 0x6b72616b);
        let steer = rng.gen_range_f64(-0.6, 0.6);
        let obstacle = (rng.gen_range_f64(-0.25, 0.25), rng.gen_range_f64(-0.1, 0.3), 0.12);
        Scene { kind, obstacle, steer, last_lap: 0, rng }
    }

    /// Ground-truth labels for the corridor scene at time `t_s`:
    /// (steer angle, collision-imminent flag). Used by the accuracy checks
    /// of the mission example.
    pub fn corridor_truth(&self, t_s: f64) -> (f64, bool) {
        match self.kind {
            SceneKind::Corridor { speed_per_s, .. } => {
                let phase = (t_s * speed_per_s).fract();
                (self.steer, phase > 0.55 && phase < 0.95)
            }
            _ => (0.0, false),
        }
    }

    /// Advance stochastic scene state to time `t_s` (corridor obstacles
    /// re-roll when passed). Call once per rendered sample.
    pub fn advance(&mut self, t_s: f64) {
        if let SceneKind::Corridor { speed_per_s, seed } = self.kind {
            let lap = (t_s * speed_per_s) as u64;
            // new obstacle + heading each "lap" through the corridor segment
            if lap != self.last_lap {
                self.last_lap = lap;
                let mut r = Rng::seed_from_u64(
                    seed ^ lap.wrapping_mul(0x9e3779b97f4a7c15),
                );
                self.steer = r.gen_range_f64(-0.6, 0.6);
                self.obstacle =
                    (r.gen_range_f64(-0.25, 0.25), r.gen_range_f64(-0.1, 0.3), 0.12);
                let _ = &self.rng; // rng reserved for future stochastic props
            }
        }
    }

    /// Intensity in [0,1] at normalized coords (x, y in [-0.5, 0.5]), time t.
    pub fn intensity(&self, x: f64, y: f64, t_s: f64) -> f64 {
        match self.kind {
            SceneKind::RotatingBar { omega_rad_s } => {
                let ang = omega_rad_s * t_s;
                let d = (x * ang.sin() - y * ang.cos()).abs();
                let r2 = x * x + y * y;
                if d < 0.07 && r2 < 0.2 {
                    1.0
                } else {
                    0.1
                }
            }
            SceneKind::TranslatingEdge { vel_per_s } => {
                let off = ((vel_per_s * t_s + 0.5).rem_euclid(1.0)) - 0.5;
                if x < off {
                    0.9
                } else {
                    0.1
                }
            }
            SceneKind::ExpandingRing { rate_per_s } => {
                let r0 = 0.05 + (rate_per_s * t_s).rem_euclid(0.4);
                let r = (x * x + y * y).sqrt();
                if r < r0 && r > r0 - 0.08 {
                    1.0
                } else {
                    0.1
                }
            }
            SceneKind::Corridor { speed_per_s, .. } => {
                let phase = (t_s * speed_per_s).fract();
                // heading line sliding toward the camera (ego-motion)
                let d = (x - self.steer * (y + 0.5 + 0.2 * phase)).abs();
                // beyond 3 sigma the Gaussian line contributes < 0.1% of
                // full scale: skip the exp (render is the simulator's
                // hottest loop — see EXPERIMENTS.md §Perf)
                let mut i = if d < 0.30 {
                    0.15 + 0.75 * (-d * d / 0.01).exp()
                } else {
                    0.15
                };
                // obstacle grows as the UAV approaches (looming)
                if phase > 0.4 {
                    let scale = (phase - 0.4) / 0.6;
                    let (ox, oy, s) = self.obstacle;
                    let s = s * (0.3 + 1.2 * scale);
                    if (x - ox).abs() < s && (y - oy).abs() < s {
                        i = 0.95;
                    }
                }
                i
            }
            SceneKind::Noise { density, .. } => {
                // deterministic hash noise: flickers with density `density`
                let xi = ((x + 0.5) * 4096.0) as u64;
                let yi = ((y + 0.5) * 4096.0) as u64;
                let ti = (t_s * 1000.0) as u64;
                let h = xi
                    .wrapping_mul(0x9e3779b97f4a7c15)
                    .wrapping_add(yi.wrapping_mul(0xbf58476d1ce4e5b9))
                    .wrapping_add(ti.wrapping_mul(0x94d049bb133111eb));
                let h = (h ^ (h >> 31)).wrapping_mul(0xbf58476d1ce4e5b9);
                if ((h >> 40) as f64 / (1u64 << 24) as f64) < density {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Render a width x height intensity image at time `t_s` (row-major).
    pub fn render(&self, width: usize, height: usize, t_s: f64) -> Vec<f32> {
        let mut img = vec![0f32; width * height];
        self.render_into(width, height, t_s, &mut img);
        img
    }

    /// Render into a caller-owned buffer (no allocation — the DVS samples
    /// at kHz rates and this is the simulator's hottest loop).
    ///
    /// Every [`SceneKind`] has a specialized loop so the kind match and
    /// all per-render / per-row invariants hoist out of the per-pixel
    /// body. The specializations share one row/pixel emission pair
    /// ([`render_rows`] / [`fill_row`]) — so the coordinate normalization
    /// cannot drift between kinds and every row lands in the contiguous
    /// lane layout the vectorized DVS scan consumes — and each is pinned
    /// pixel-identical to the reference [`Scene::intensity`] by
    /// `specialized_render_matches_generic_path`:
    ///
    /// * **corridor** (the mission workload) — row-wise: the heading
    ///   line's center is constant per row, so only pixels within the
    ///   line's 3-sigma support pay an `exp`, and obstacle membership is
    ///   two range checks;
    /// * **bar** — `sin`/`cos` of the bar angle computed once per render
    ///   instead of twice per pixel;
    /// * **edge** — every row is identical: render row 0, memcpy the rest;
    /// * **ring** — ring radius and band hoisted per render, `y*y` per row;
    /// * **noise** — the row and time terms of the hash mix computed once
    ///   per row / per render (EXPERIMENTS.md §Perf).
    pub fn render_into(&self, width: usize, height: usize, t_s: f64, img: &mut [f32]) {
        assert_eq!(img.len(), width * height);
        let inv_w = 1.0 / width as f64;
        match self.kind {
            SceneKind::Corridor { speed_per_s, .. } => {
                let phase = (t_s * speed_per_s).fract();
                let looming = phase > 0.4;
                let scale = if looming { (phase - 0.4) / 0.6 } else { 0.0 };
                let (ox, oy, s0) = self.obstacle;
                let os = s0 * (0.3 + 1.2 * scale);
                render_rows(img, width, height, |y, row| {
                    let center = self.steer * (y + 0.5 + 0.2 * phase);
                    let in_obst_row = looming && (y - oy).abs() < os;
                    fill_row(row, inv_w, |x| {
                        let d = (x - center).abs();
                        let mut i = if d < 0.30 {
                            0.15 + 0.75 * (-d * d / 0.01).exp()
                        } else {
                            0.15
                        };
                        if in_obst_row && (x - ox).abs() < os {
                            i = 0.95;
                        }
                        i as f32
                    });
                });
            }
            SceneKind::RotatingBar { omega_rad_s } => {
                let ang = omega_rad_s * t_s;
                let (sin_a, cos_a) = (ang.sin(), ang.cos());
                render_rows(img, width, height, |y, row| {
                    let yc = y * cos_a;
                    let y2 = y * y;
                    fill_row(row, inv_w, |x| {
                        let d = (x * sin_a - yc).abs();
                        let r2 = x * x + y2;
                        // f64 intensity then cast, exactly like intensity()
                        (if d < 0.07 && r2 < 0.2 { 1.0f64 } else { 0.1 }) as f32
                    });
                });
            }
            SceneKind::TranslatingEdge { vel_per_s } => {
                if height == 0 {
                    return;
                }
                let off = ((vel_per_s * t_s + 0.5).rem_euclid(1.0)) - 0.5;
                fill_row(&mut img[..width], inv_w, |x| {
                    (if x < off { 0.9f64 } else { 0.1 }) as f32
                });
                for yy in 1..height {
                    img.copy_within(0..width, yy * width);
                }
            }
            SceneKind::ExpandingRing { rate_per_s } => {
                let r0 = 0.05 + (rate_per_s * t_s).rem_euclid(0.4);
                let r_in = r0 - 0.08;
                render_rows(img, width, height, |y, row| {
                    let y2 = y * y;
                    fill_row(row, inv_w, |x| {
                        let r = (x * x + y2).sqrt();
                        (if r < r0 && r > r_in { 1.0f64 } else { 0.1 }) as f32
                    });
                });
            }
            SceneKind::Noise { density, .. } => {
                let ti = (t_s * 1000.0) as u64;
                let t_term = ti.wrapping_mul(0x94d049bb133111eb);
                render_rows(img, width, height, |y, row| {
                    let yi = ((y + 0.5) * 4096.0) as u64;
                    let y_term = yi.wrapping_mul(0xbf58476d1ce4e5b9);
                    fill_row(row, inv_w, |x| {
                        let xi = ((x + 0.5) * 4096.0) as u64;
                        let h = xi
                            .wrapping_mul(0x9e3779b97f4a7c15)
                            .wrapping_add(y_term)
                            .wrapping_add(t_term);
                        let h = (h ^ (h >> 31)).wrapping_mul(0xbf58476d1ce4e5b9);
                        (if ((h >> 40) as f64 / (1u64 << 24) as f64) < density {
                            1.0f64
                        } else {
                            0.0
                        }) as f32
                    });
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intensities_in_range() {
        let kinds = [
            SceneKind::RotatingBar { omega_rad_s: 2.0 },
            SceneKind::TranslatingEdge { vel_per_s: 0.5 },
            SceneKind::ExpandingRing { rate_per_s: 0.3 },
            SceneKind::Corridor { speed_per_s: 0.5, seed: 1 },
            SceneKind::Noise { density: 0.1, seed: 2 },
        ];
        for kind in kinds {
            let s = Scene::new(kind);
            for &t in &[0.0, 0.33, 1.7] {
                let img = s.render(16, 16, t);
                assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)), "{kind:?}");
            }
        }
    }

    #[test]
    fn rotating_bar_moves() {
        let s = Scene::new(SceneKind::RotatingBar { omega_rad_s: 3.0 });
        let a = s.render(32, 32, 0.0);
        let b = s.render(32, 32, 0.2);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "bar should move between samples");
    }

    #[test]
    fn noise_density_scales_flicker() {
        let lo = Scene::new(SceneKind::Noise { density: 0.01, seed: 0 });
        let hi = Scene::new(SceneKind::Noise { density: 0.3, seed: 0 });
        let mean = |s: &Scene| -> f64 {
            let img = s.render(64, 64, 0.5);
            img.iter().map(|&v| v as f64).sum::<f64>() / img.len() as f64
        };
        assert!(mean(&hi) > 5.0 * mean(&lo));
    }

    #[test]
    fn render_deterministic() {
        let s1 = Scene::new(SceneKind::Corridor { speed_per_s: 0.5, seed: 7 });
        let s2 = Scene::new(SceneKind::Corridor { speed_per_s: 0.5, seed: 7 });
        assert_eq!(s1.render(24, 24, 0.7), s2.render(24, 24, 0.7));
    }

    #[test]
    fn specialized_corridor_render_matches_generic_path() {
        // the row-wise fast renderer must be pixel-identical to the
        // reference per-pixel intensity()
        let s = Scene::new(SceneKind::Corridor { speed_per_s: 0.7, seed: 5 });
        for &t in &[0.05, 0.3, 0.55, 0.83, 1.4] {
            let fast = s.render(132, 128, t);
            for yy in 0..128usize {
                for xx in 0..132usize {
                    let y = (yy as f64 + 0.5) / 128.0 - 0.5;
                    let x = (xx as f64 + 0.5) / 132.0 - 0.5;
                    let want = s.intensity(x, y, t) as f32;
                    let got = fast[yy * 132 + xx];
                    assert!(
                        (want - got).abs() < 1e-6,
                        "t={t} ({xx},{yy}): {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn specialized_render_matches_generic_path_for_every_kind() {
        // each kind's hoisted row-wise renderer must be bit-identical to
        // the reference per-pixel intensity() (the replay-identity
        // contract of sensor traces rides on this)
        let kinds = [
            SceneKind::RotatingBar { omega_rad_s: 7.0 },
            SceneKind::TranslatingEdge { vel_per_s: 0.4 },
            SceneKind::ExpandingRing { rate_per_s: 0.6 },
            SceneKind::Corridor { speed_per_s: 0.7, seed: 5 },
            SceneKind::Noise { density: 0.12, seed: 3 },
        ];
        for kind in kinds {
            let s = Scene::new(kind);
            for &t in &[0.0, 0.05, 0.3, 0.55, 0.83, 1.4] {
                let (w, h) = (66, 64);
                let fast = s.render(w, h, t);
                for yy in 0..h {
                    for xx in 0..w {
                        let y = (yy as f64 + 0.5) / h as f64 - 0.5;
                        let x = (xx as f64 + 0.5) / w as f64 - 0.5;
                        let want = s.intensity(x, y, t) as f32;
                        let got = fast[yy * w + xx];
                        assert_eq!(
                            want.to_bits(),
                            got.to_bits(),
                            "{kind:?} t={t} ({xx},{yy}): {got} vs {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn corridor_truth_flags_looming_phase() {
        let s = Scene::new(SceneKind::Corridor { speed_per_s: 1.0, seed: 3 });
        let (_, c0) = s.corridor_truth(0.1);
        let (_, c1) = s.corridor_truth(0.7);
        assert!(!c0 && c1);
    }
}
