//! DVS event-camera simulator (DVS132S-class front end).
//!
//! Standard DVS pixel model: each pixel holds the log-intensity at its last
//! event; when the current log-intensity differs by more than the contrast
//! threshold C, it emits ON/OFF events (one per threshold crossing), subject
//! to a refractory period. Background-activity noise is Poisson per pixel.
//!
//! The simulator is sampled: `step(scene, t_ns)` compares against the
//! previous sample and linearly interpolates event timestamps within the
//! sample interval, producing the time-sorted COO stream the AER peripheral
//! (soc::peripherals) carries into the SoC.

use crate::event::{Event, EventWindow, Polarity};
use crate::util::rng::Rng;
use crate::sensors::scene::Scene;

/// DVS pixel-array simulator.
#[derive(Debug, Clone)]
pub struct DvsSim {
    pub width: usize,
    pub height: usize,
    /// Contrast threshold on log intensity (typ. 0.2–0.4).
    pub threshold: f64,
    /// Per-pixel refractory period (ns), modeled as a cap on the number
    /// of events one pixel may emit per sample interval.
    pub refractory_ns: u64,
    /// Background-activity noise rate per pixel (Hz).
    pub noise_rate_hz: f64,
    last_log: Vec<f64>,
    /// Per-pixel intensity band [lo, hi]: while the rendered intensity
    /// stays inside, no threshold crossing is possible and the pixel is
    /// skipped without touching `ln` (the fast path that makes kHz
    /// sampling at 132x128 tractable — EXPERIMENTS.md §Perf).
    band_lo: Vec<f32>,
    band_hi: Vec<f32>,
    render_buf: Vec<f32>,
    staged: Vec<(u64, usize, Polarity)>,
    last_t_ns: u64,
    primed: bool,
    /// The construction seed, kept so [`DvsSim::reset`] can rewind the
    /// noise RNG to its power-on state.
    seed: u64,
    rng: Rng,
}

/// Floor for the log-intensity transform (keeps log finite on black).
const EPS: f64 = 0.02;

impl DvsSim {
    pub fn new(width: usize, height: usize, seed: u64) -> Self {
        DvsSim {
            width,
            height,
            threshold: 0.25,
            refractory_ns: 100_000, // 100 us, ~DVS132S at nominal biases
            noise_rate_hz: 2.0,
            last_log: vec![0.0; width * height],
            band_lo: vec![0.0; width * height],
            band_hi: vec![0.0; width * height],
            render_buf: vec![0.0; width * height],
            staged: Vec::new(),
            last_t_ns: 0,
            primed: false,
            seed,
            rng: Rng::seed_from_u64(seed),
        }
    }

    /// Recompute the no-event intensity band of pixel `i` from its stored
    /// log level: crossing happens when |ln(I+eps) - L| >= C.
    fn reband(&mut self, i: usize) {
        let l = self.last_log[i];
        self.band_lo[i] = ((l - self.threshold).exp() - EPS) as f32;
        self.band_hi[i] = ((l + self.threshold).exp() - EPS) as f32;
    }

    /// Reset the sensor to its power-on state (e.g. between mission
    /// segments): pixel memories, bands, staged events, the render buffer
    /// and the noise RNG all rewind, so a reset sensor replays the exact
    /// event stream a freshly-constructed one would.
    pub fn reset(&mut self) {
        self.last_log.iter_mut().for_each(|v| *v = 0.0);
        self.band_lo.iter_mut().for_each(|v| *v = 0.0);
        self.band_hi.iter_mut().for_each(|v| *v = 0.0);
        self.render_buf.iter_mut().for_each(|v| *v = 0.0);
        self.staged.clear();
        self.primed = false;
        self.last_t_ns = 0;
        self.rng = Rng::seed_from_u64(self.seed);
    }

    /// Sample the scene at `t_ns` and emit events since the last sample.
    ///
    /// The first call primes pixel memories and emits nothing (a real DVS
    /// emits a burst at power-on; we suppress it like the sensor's own
    /// initialization masking does).
    pub fn step(&mut self, scene: &Scene, t_ns: u64) -> EventWindow {
        let mut win = EventWindow::new(self.width, self.height);
        self.step_into(scene, t_ns, &mut win);
        win
    }

    /// The allocation-free form of [`DvsSim::step`]: sample the scene at
    /// `t_ns` and *append* the new events to `win`, which must share the
    /// sensor's geometry. The mission pipeline reuses one window buffer
    /// across every sample of an inference window (EXPERIMENTS.md §Perf).
    pub fn step_into(&mut self, scene: &Scene, t_ns: u64, win: &mut EventWindow) {
        debug_assert_eq!((win.width, win.height), (self.width, self.height));
        let mut img = std::mem::take(&mut self.render_buf);
        scene.render_into(self.width, self.height, t_ns as f64 * 1e-9, &mut img);
        if !self.primed {
            for i in 0..img.len() {
                self.last_log[i] = ((img[i] as f64) + EPS).ln();
                self.reband(i);
            }
            self.primed = true;
            self.last_t_ns = t_ns;
            self.render_buf = img;
            return;
        }
        let dt = t_ns.saturating_sub(self.last_t_ns).max(1);
        let mut staged = std::mem::take(&mut self.staged);
        staged.clear();
        // noise first: Poisson-thinned over the whole array so the fast
        // path below never rolls the RNG per pixel
        let p_noise = self.noise_rate_hz * dt as f64 * 1e-9;
        if p_noise > 0.0 {
            let expected = p_noise * img.len() as f64;
            let mut budget = expected.floor() as usize;
            if self.rng.gen_f64() < expected - budget as f64 {
                budget += 1;
            }
            for _ in 0..budget {
                let i = self.rng.gen_range_usize(0, img.len());
                let ts = self.last_t_ns + self.rng.gen_below(dt);
                let pol = if self.rng.gen_bool() { Polarity::On } else { Polarity::Off };
                staged.push((ts, i, pol));
            }
        }
        for i in 0..img.len() {
            // fast path: intensity inside the pixel's no-crossing band
            let v = img[i];
            if v > self.band_lo[i] && v < self.band_hi[i] {
                continue;
            }
            let l_new = ((v as f64) + EPS).ln();
            let mut dl = l_new - self.last_log[i];
            let pol = if dl >= 0.0 { Polarity::On } else { Polarity::Off };
            let mut n_cross = (dl.abs() / self.threshold) as usize;
            // refractory limits the event rate per pixel
            let max_ev = (dt / self.refractory_ns.max(1)).max(1) as usize;
            n_cross = n_cross.min(max_ev);
            if n_cross > 0 {
                for k in 0..n_cross {
                    // interpolate crossing times across the interval
                    let frac = (k as f64 + 1.0) / (n_cross as f64 + 1.0);
                    let ts = self.last_t_ns + (frac * dt as f64) as u64;
                    staged.push((ts, i, pol));
                }
                let signed = self.threshold * n_cross as f64;
                dl = if pol == Polarity::On { signed } else { -signed };
                self.last_log[i] += dl;
                self.reband(i);
            }
        }
        staged.sort_unstable_by_key(|&(t, i, _)| (t, i));
        for &(t, i, p) in &staged {
            win.push(Event {
                t_ns: t,
                x: (i % self.width) as u16,
                y: (i / self.width) as u16,
                polarity: p,
            });
        }
        self.staged = staged;
        self.render_buf = img;
        self.last_t_ns = t_ns;
    }

    /// Convenience: run the sensor over [0, duration) at `sample_hz`,
    /// concatenating all events into one window.
    pub fn capture(&mut self, scene: &mut Scene, duration_s: f64, sample_hz: f64) -> EventWindow {
        let mut all = EventWindow::new(self.width, self.height);
        let steps = (duration_s * sample_hz) as usize;
        for k in 0..=steps {
            let t_ns = (k as f64 / sample_hz * 1e9) as u64;
            scene.advance(t_ns as f64 * 1e-9);
            let w = self.step(scene, t_ns);
            for e in w.events {
                all.push(e);
            }
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensors::scene::SceneKind;

    #[test]
    fn static_scene_yields_only_noise() {
        let mut dvs = DvsSim::new(32, 32, 1);
        dvs.noise_rate_hz = 0.0;
        let scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 0.0 });
        dvs.step(&scene, 0);
        let w = dvs.step(&scene, 10_000_000);
        assert!(w.is_empty(), "static scene must emit no events, got {}", w.len());
    }

    #[test]
    fn moving_edge_emits_polarity_pairs() {
        let mut dvs = DvsSim::new(64, 64, 2);
        dvs.noise_rate_hz = 0.0;
        // fast edge over >1 period so it wraps: ON at the advancing front,
        // an OFF burst when the bright region resets
        let mut scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 1.0 });
        let w = dvs.capture(&mut scene, 1.2, 200.0);
        assert!(w.len() > 50, "moving edge must produce events");
        let (on, off) = w.polarity_counts();
        assert!(on > 0 && off > 0, "edge motion makes both polarities");
    }

    #[test]
    fn events_are_time_sorted_and_in_bounds() {
        let mut dvs = DvsSim::new(48, 40, 3);
        let mut scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: 6.0 });
        let w = dvs.capture(&mut scene, 0.1, 500.0);
        let mut last = 0;
        for e in &w.events {
            assert!(e.t_ns >= last);
            assert!((e.x as usize) < 48 && (e.y as usize) < 40);
            last = e.t_ns;
        }
    }

    #[test]
    fn noise_rate_controls_activity() {
        let act = |noise: f64| {
            let mut dvs = DvsSim::new(32, 32, 4);
            dvs.noise_rate_hz = noise;
            let mut scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 0.0 });
            let w = dvs.capture(&mut scene, 0.5, 100.0);
            w.activity()
        };
        assert!(act(200.0) > 10.0 * act(2.0).max(1e-6));
    }

    #[test]
    fn faster_motion_more_events() {
        let count = |omega: f64| {
            let mut dvs = DvsSim::new(64, 64, 5);
            dvs.noise_rate_hz = 0.0;
            let mut scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: omega });
            dvs.capture(&mut scene, 0.2, 400.0).len()
        };
        assert!(count(12.0) > count(2.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut dvs = DvsSim::new(32, 32, 42);
            let mut scene = Scene::new(SceneKind::Corridor { speed_per_s: 1.0, seed: 9 });
            dvs.capture(&mut scene, 0.1, 200.0).events
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn reset_restores_power_on_state() {
        let seq = |dvs: &mut DvsSim| {
            let mut scene = Scene::new(SceneKind::Corridor { speed_per_s: 1.0, seed: 4 });
            dvs.capture(&mut scene, 0.1, 300.0).events
        };
        let mut fresh = DvsSim::new(32, 32, 8);
        fresh.noise_rate_hz = 50.0;
        let want = seq(&mut fresh);
        assert!(!want.is_empty());
        // drive the sensor hard on a different scene, then reset: the
        // replayed capture must match a fresh sensor event for event
        let mut reused = DvsSim::new(32, 32, 8);
        reused.noise_rate_hz = 50.0;
        let mut other = Scene::new(SceneKind::RotatingBar { omega_rad_s: 9.0 });
        reused.capture(&mut other, 0.05, 500.0);
        reused.reset();
        assert_eq!(seq(&mut reused), want);
    }

    #[test]
    fn step_into_appends_across_samples() {
        let mut a = DvsSim::new(32, 32, 6);
        let mut b = DvsSim::new(32, 32, 6);
        let scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: 8.0 });
        let mut acc = EventWindow::new(32, 32);
        let mut want: Vec<Event> = Vec::new();
        for k in 0..20u64 {
            let t = k * 2_000_000;
            a.step_into(&scene, t, &mut acc);
            want.extend(b.step(&scene, t).events);
        }
        assert_eq!(acc.events, want);
    }
}
