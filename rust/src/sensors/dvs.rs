//! DVS event-camera simulator (DVS132S-class front end).
//!
//! Standard DVS pixel model: each pixel holds the log-intensity at its last
//! event; when the current log-intensity differs by more than the contrast
//! threshold C, it emits ON/OFF events (one per threshold crossing), subject
//! to a refractory period. Background-activity noise is Poisson per pixel.
//!
//! The simulator is sampled: `step(scene, t_ns)` compares against the
//! previous sample and linearly interpolates event timestamps within the
//! sample interval, producing the time-sorted COO stream the AER peripheral
//! (soc::peripherals) carries into the SoC.
//!
//! # Vectorized front end (DESIGN.md §11)
//!
//! Pixel state is structure-of-arrays (`last_log` / `band_lo` / `band_hi`
//! as contiguous per-plane buffers) and the per-sample scan runs in fixed
//! [`DVS_LANES`]-wide f32 lanes, the way the real chip's sensor interface
//! handles events in parallel rather than pixel-serially:
//!
//! 1. **masked scan** — each lane chunk folds the per-pixel no-crossing
//!    band check into one branchless bitmask; an event-sparse chunk costs
//!    a single test instead of `DVS_LANES` branchy compares
//!    ([`scan_out_of_band`]);
//! 2. **gather → batched math** — the (sparse) out-of-band pixel indices
//!    are gathered into a compact buffer and the `ln` transform runs over
//!    it in one tight pass, out of the branchy scan loop;
//! 3. **scatter** — each crossing pixel emits its events and updates its
//!    SoA state through [`DvsSim::emit_pixel`], the single crossing body
//!    shared with the scalar reference path so the two cannot drift.
//!
//! The hard contract: the vectorized step is **bit-identical** to the
//! scalar reference [`DvsSim::step_into_scalar`] — same events, same
//! order, same band state, same RNG draw sequence for the noise budget —
//! pinned by `prop_vectorized_step_equals_scalar` and the sensor-trace
//! fingerprints in `tests/integration_trace.rs`.

use crate::event::{Event, EventWindow, Polarity};
use crate::sensors::scene::Scene;
use crate::util::rng::Rng;

/// Lane width of the vectorized pixel scan: 8 f32 lanes fill one 256-bit
/// vector register; on narrower ISAs LLVM splits the chunk, on wider ones
/// it unrolls — either way the mask fold stays branchless.
pub const DVS_LANES: usize = 8;

/// DVS pixel-array simulator.
#[derive(Debug, Clone)]
pub struct DvsSim {
    pub width: usize,
    pub height: usize,
    /// Contrast threshold on log intensity (typ. 0.2–0.4).
    pub threshold: f64,
    /// Per-pixel refractory period (ns), modeled as a cap on the number
    /// of events one pixel may emit per sample interval.
    pub refractory_ns: u64,
    /// Background-activity noise rate per pixel (Hz).
    pub noise_rate_hz: f64,
    /// SoA pixel memory: log-intensity at each pixel's last event.
    last_log: Vec<f64>,
    /// Per-pixel intensity band [lo, hi]: while the rendered intensity
    /// stays inside, no threshold crossing is possible and the pixel is
    /// skipped without touching `ln` (the fast path that makes kHz
    /// sampling at 132x128 tractable — EXPERIMENTS.md §Perf).
    band_lo: Vec<f32>,
    band_hi: Vec<f32>,
    render_buf: Vec<f32>,
    staged: Vec<(u64, usize, Polarity)>,
    /// Gathered out-of-band pixel indices (ascending), reused per step.
    crossing: Vec<u32>,
    /// Batched `ln` results for the gathered pixels, reused per step.
    log_batch: Vec<f64>,
    last_t_ns: u64,
    primed: bool,
    /// The construction seed, kept so [`DvsSim::reset`] can rewind the
    /// noise RNG to its power-on state.
    seed: u64,
    rng: Rng,
}

/// Floor for the log-intensity transform (keeps log finite on black).
const EPS: f64 = 0.02;

/// Fold the per-pixel band check into a per-chunk lane bitmask: a chunk
/// of [`DVS_LANES`] pixels is compared branchlessly against its band
/// planes and reduced to one `u32` mask, so event-sparse chunks cost a
/// single test. Out-of-band indices land in `out` in ascending order —
/// exactly the order the scalar reference loop visits them.
fn scan_out_of_band(img: &[f32], lo: &[f32], hi: &[f32], out: &mut Vec<u32>) {
    debug_assert_eq!(img.len(), lo.len());
    debug_assert_eq!(img.len(), hi.len());
    debug_assert!(img.len() <= u32::MAX as usize, "pixel index must fit u32");
    out.clear();
    let n = img.len();
    let head = n - n % DVS_LANES;
    let mut base = 0;
    while base < head {
        let mut mask = 0u32;
        for lane in 0..DVS_LANES {
            let i = base + lane;
            // out-of-band ⇔ the scalar fast path would fall through
            let in_band = img[i] > lo[i] && img[i] < hi[i];
            mask |= (!in_band as u32) << lane;
        }
        if mask != 0 {
            let mut m = mask;
            while m != 0 {
                let lane = m.trailing_zeros() as usize;
                out.push((base + lane) as u32);
                m &= m - 1;
            }
        }
        base += DVS_LANES;
    }
    // tail lanes: the last n % DVS_LANES pixels run the same predicate
    // one at a time
    for i in head..n {
        let in_band = img[i] > lo[i] && img[i] < hi[i];
        if !in_band {
            out.push(i as u32);
        }
    }
    debug_assert!(
        out.windows(2).all(|w| w[0] < w[1]),
        "lane scan must yield strictly ascending pixel indices"
    );
}

impl DvsSim {
    pub fn new(width: usize, height: usize, seed: u64) -> Self {
        DvsSim {
            width,
            height,
            threshold: 0.25,
            refractory_ns: 100_000, // 100 us, ~DVS132S at nominal biases
            noise_rate_hz: 2.0,
            last_log: vec![0.0; width * height],
            band_lo: vec![0.0; width * height],
            band_hi: vec![0.0; width * height],
            render_buf: vec![0.0; width * height],
            staged: Vec::new(),
            crossing: Vec::new(),
            log_batch: Vec::new(),
            last_t_ns: 0,
            primed: false,
            seed,
            rng: Rng::seed_from_u64(seed),
        }
    }

    /// The threshold's exp pair, hoisted out of the crossing loop: band
    /// edges are `exp(L ± C) - EPS = exp(L)·exp(±C) - EPS`, so a crossing
    /// pixel pays one `exp` instead of two.
    #[inline]
    fn exp_pair(&self) -> (f64, f64) {
        (self.threshold.exp(), (-self.threshold).exp())
    }

    /// The no-event intensity band of a pixel whose stored log level is
    /// `l`: crossing happens when |ln(I+eps) - L| >= C. `exp_th` /
    /// `exp_nth` are the hoisted `exp(±C)` pair from [`DvsSim::exp_pair`].
    #[inline]
    fn band_edges(l: f64, exp_th: f64, exp_nth: f64) -> (f32, f32) {
        let e = l.exp();
        ((e * exp_nth - EPS) as f32, (e * exp_th - EPS) as f32)
    }

    /// Reset the sensor to its power-on state (e.g. between mission
    /// segments): pixel memories, bands, staged events, the render buffer
    /// and the noise RNG all rewind, so a reset sensor replays the exact
    /// event stream a freshly-constructed one would.
    pub fn reset(&mut self) {
        self.last_log.iter_mut().for_each(|v| *v = 0.0);
        self.band_lo.iter_mut().for_each(|v| *v = 0.0);
        self.band_hi.iter_mut().for_each(|v| *v = 0.0);
        self.render_buf.iter_mut().for_each(|v| *v = 0.0);
        self.staged.clear();
        self.crossing.clear();
        self.log_batch.clear();
        self.primed = false;
        self.last_t_ns = 0;
        self.rng = Rng::seed_from_u64(self.seed);
    }

    /// Sample the scene at `t_ns` and emit events since the last sample.
    ///
    /// The first call primes pixel memories and emits nothing (a real DVS
    /// emits a burst at power-on; we suppress it like the sensor's own
    /// initialization masking does).
    pub fn step(&mut self, scene: &Scene, t_ns: u64) -> EventWindow {
        let mut win = EventWindow::new(self.width, self.height);
        self.step_into(scene, t_ns, &mut win);
        win
    }

    /// The allocation-free form of [`DvsSim::step`]: sample the scene at
    /// `t_ns` and *append* the new events to `win`, which must share the
    /// sensor's geometry. The mission pipeline reuses one window buffer
    /// across every sample of an inference window (EXPERIMENTS.md §Perf).
    ///
    /// This is the vectorized path (module docs): lane-masked band scan,
    /// then a gather → batched-`ln` → scatter pass over the sparse
    /// out-of-band pixels. Bit-identical to
    /// [`DvsSim::step_into_scalar`].
    pub fn step_into(&mut self, scene: &Scene, t_ns: u64, win: &mut EventWindow) {
        debug_assert_eq!((win.width, win.height), (self.width, self.height));
        let mut img = std::mem::take(&mut self.render_buf);
        scene.render_into(self.width, self.height, t_ns as f64 * 1e-9, &mut img);
        if !self.primed {
            self.prime(&img, t_ns);
            self.render_buf = img;
            return;
        }
        let dt = t_ns.saturating_sub(self.last_t_ns).max(1);
        let mut staged = std::mem::take(&mut self.staged);
        staged.clear();
        self.stage_noise(img.len(), dt, &mut staged);

        // 1. lane-masked scan over the SoA band planes
        let mut crossing = std::mem::take(&mut self.crossing);
        scan_out_of_band(&img, &self.band_lo, &self.band_hi, &mut crossing);

        // 2. gather the crossing pixels and batch the log transform over
        //    the compact buffer (out of the branchy scan loop)
        let mut log_batch = std::mem::take(&mut self.log_batch);
        log_batch.clear();
        log_batch.extend(crossing.iter().map(|&i| ((img[i as usize] as f64) + EPS).ln()));

        // 3. scatter: emit events + update SoA state per crossing pixel
        let (exp_th, exp_nth) = self.exp_pair();
        for (&i, &l_new) in crossing.iter().zip(&log_batch) {
            self.emit_pixel(i as usize, l_new, dt, exp_th, exp_nth, &mut staged);
        }
        self.crossing = crossing;
        self.log_batch = log_batch;
        self.commit(staged, img, t_ns, win);
    }

    /// The scalar reference step: the pre-vectorization per-pixel loop,
    /// kept (behind the default-on `scalar-ref` feature) as the ground
    /// truth the lane path is property-pinned against, and as the
    /// baseline leg of hotpath bench §7. Shares the noise staging and the
    /// crossing body with the vectorized path — only the scan differs.
    #[cfg(any(test, feature = "scalar-ref"))]
    pub fn step_into_scalar(&mut self, scene: &Scene, t_ns: u64, win: &mut EventWindow) {
        debug_assert_eq!((win.width, win.height), (self.width, self.height));
        let mut img = std::mem::take(&mut self.render_buf);
        scene.render_into(self.width, self.height, t_ns as f64 * 1e-9, &mut img);
        if !self.primed {
            self.prime(&img, t_ns);
            self.render_buf = img;
            return;
        }
        let dt = t_ns.saturating_sub(self.last_t_ns).max(1);
        let mut staged = std::mem::take(&mut self.staged);
        staged.clear();
        self.stage_noise(img.len(), dt, &mut staged);
        let (exp_th, exp_nth) = self.exp_pair();
        for i in 0..img.len() {
            // fast path: intensity inside the pixel's no-crossing band
            let v = img[i];
            if v > self.band_lo[i] && v < self.band_hi[i] {
                continue;
            }
            let l_new = ((v as f64) + EPS).ln();
            self.emit_pixel(i, l_new, dt, exp_th, exp_nth, &mut staged);
        }
        self.commit(staged, img, t_ns, win);
    }

    /// Allocating convenience over [`DvsSim::step_into_scalar`], the
    /// twin of [`DvsSim::step`] (hotpath bench §7).
    #[cfg(any(test, feature = "scalar-ref"))]
    pub fn step_scalar(&mut self, scene: &Scene, t_ns: u64) -> EventWindow {
        let mut win = EventWindow::new(self.width, self.height);
        self.step_into_scalar(scene, t_ns, &mut win);
        win
    }

    /// The SoA pixel state `(last_log, band_lo, band_hi)` — exposed so
    /// the scalar/vectorized equivalence property can assert the two
    /// paths leave identical state behind, not just identical events.
    #[cfg(any(test, feature = "scalar-ref"))]
    pub fn band_state(&self) -> (&[f64], &[f32], &[f32]) {
        (&self.last_log, &self.band_lo, &self.band_hi)
    }

    /// The next u64 the noise RNG would draw, without advancing it:
    /// proves the vectorized path leaves the RNG at the same position as
    /// the scalar reference (the noise budget contract).
    #[cfg(any(test, feature = "scalar-ref"))]
    pub fn rng_probe(&self) -> u64 {
        self.rng.clone().next_u64()
    }

    /// First-sample initialization: prime pixel memories and bands from
    /// the rendered image, emitting nothing.
    fn prime(&mut self, img: &[f32], t_ns: u64) {
        let (exp_th, exp_nth) = self.exp_pair();
        for (i, &v) in img.iter().enumerate() {
            let l = ((v as f64) + EPS).ln();
            self.last_log[i] = l;
            let (lo, hi) = Self::band_edges(l, exp_th, exp_nth);
            self.band_lo[i] = lo;
            self.band_hi[i] = hi;
        }
        self.primed = true;
        self.last_t_ns = t_ns;
    }

    /// Poisson-thinned background noise over the whole array, staged
    /// before the pixel scan so the fast path never rolls the RNG per
    /// pixel. Shared by both step paths: the RNG draw sequence is part of
    /// the bit-identity contract.
    fn stage_noise(&mut self, n_px: usize, dt: u64, staged: &mut Vec<(u64, usize, Polarity)>) {
        let p_noise = self.noise_rate_hz * dt as f64 * 1e-9;
        if p_noise > 0.0 {
            let expected = p_noise * n_px as f64;
            let mut budget = expected.floor() as usize;
            if self.rng.gen_f64() < expected - budget as f64 {
                budget += 1;
            }
            for _ in 0..budget {
                let i = self.rng.gen_range_usize(0, n_px);
                let ts = self.last_t_ns + self.rng.gen_below(dt);
                let pol = if self.rng.gen_bool() { Polarity::On } else { Polarity::Off };
                staged.push((ts, i, pol));
            }
        }
    }

    /// The crossing body: emit the threshold-crossing events of
    /// out-of-band pixel `i` (log level `l_new`) and update its SoA state.
    /// Shared verbatim by the vectorized and scalar paths so they cannot
    /// drift.
    #[inline]
    fn emit_pixel(
        &mut self,
        i: usize,
        l_new: f64,
        dt: u64,
        exp_th: f64,
        exp_nth: f64,
        staged: &mut Vec<(u64, usize, Polarity)>,
    ) {
        let mut dl = l_new - self.last_log[i];
        let pol = if dl >= 0.0 { Polarity::On } else { Polarity::Off };
        let mut n_cross = (dl.abs() / self.threshold) as usize;
        // refractory limits the event rate per pixel
        let max_ev = (dt / self.refractory_ns.max(1)).max(1) as usize;
        n_cross = n_cross.min(max_ev);
        if n_cross > 0 {
            for k in 0..n_cross {
                // interpolate crossing times across the interval
                let frac = (k as f64 + 1.0) / (n_cross as f64 + 1.0);
                let ts = self.last_t_ns + (frac * dt as f64) as u64;
                staged.push((ts, i, pol));
            }
            let signed = self.threshold * n_cross as f64;
            dl = if pol == Polarity::On { signed } else { -signed };
            self.last_log[i] += dl;
            let (lo, hi) = Self::band_edges(self.last_log[i], exp_th, exp_nth);
            self.band_lo[i] = lo;
            self.band_hi[i] = hi;
        }
    }

    /// Shared step epilogue: time-sort the staged events, append them to
    /// `win`, and park the reusable buffers for the next sample.
    fn commit(
        &mut self,
        mut staged: Vec<(u64, usize, Polarity)>,
        img: Vec<f32>,
        t_ns: u64,
        win: &mut EventWindow,
    ) {
        staged.sort_unstable_by_key(|&(t, i, _)| (t, i));
        for &(t, i, p) in &staged {
            win.push(Event {
                t_ns: t,
                x: (i % self.width) as u16,
                y: (i / self.width) as u16,
                polarity: p,
            });
        }
        self.staged = staged;
        self.render_buf = img;
        self.last_t_ns = t_ns;
    }

    /// Convenience: run the sensor over [0, duration) at `sample_hz`,
    /// concatenating all events into one window.
    pub fn capture(&mut self, scene: &mut Scene, duration_s: f64, sample_hz: f64) -> EventWindow {
        let mut all = EventWindow::new(self.width, self.height);
        let steps = (duration_s * sample_hz) as usize;
        for k in 0..=steps {
            let t_ns = (k as f64 / sample_hz * 1e9) as u64;
            scene.advance(t_ns as f64 * 1e-9);
            let w = self.step(scene, t_ns);
            for e in w.events {
                all.push(e);
            }
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sensors::scene::SceneKind;

    #[test]
    fn static_scene_yields_only_noise() {
        let mut dvs = DvsSim::new(32, 32, 1);
        dvs.noise_rate_hz = 0.0;
        let scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 0.0 });
        dvs.step(&scene, 0);
        let w = dvs.step(&scene, 10_000_000);
        assert!(w.is_empty(), "static scene must emit no events, got {}", w.len());
    }

    #[test]
    fn moving_edge_emits_polarity_pairs() {
        let mut dvs = DvsSim::new(64, 64, 2);
        dvs.noise_rate_hz = 0.0;
        // fast edge over >1 period so it wraps: ON at the advancing front,
        // an OFF burst when the bright region resets
        let mut scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 1.0 });
        let w = dvs.capture(&mut scene, 1.2, 200.0);
        assert!(w.len() > 50, "moving edge must produce events");
        let (on, off) = w.polarity_counts();
        assert!(on > 0 && off > 0, "edge motion makes both polarities");
    }

    #[test]
    fn events_are_time_sorted_and_in_bounds() {
        let mut dvs = DvsSim::new(48, 40, 3);
        let mut scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: 6.0 });
        let w = dvs.capture(&mut scene, 0.1, 500.0);
        let mut last = 0;
        for e in &w.events {
            assert!(e.t_ns >= last);
            assert!((e.x as usize) < 48 && (e.y as usize) < 40);
            last = e.t_ns;
        }
    }

    #[test]
    fn noise_rate_controls_activity() {
        let act = |noise: f64| {
            let mut dvs = DvsSim::new(32, 32, 4);
            dvs.noise_rate_hz = noise;
            let mut scene = Scene::new(SceneKind::TranslatingEdge { vel_per_s: 0.0 });
            let w = dvs.capture(&mut scene, 0.5, 100.0);
            w.activity()
        };
        assert!(act(200.0) > 10.0 * act(2.0).max(1e-6));
    }

    #[test]
    fn faster_motion_more_events() {
        let count = |omega: f64| {
            let mut dvs = DvsSim::new(64, 64, 5);
            dvs.noise_rate_hz = 0.0;
            let mut scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: omega });
            dvs.capture(&mut scene, 0.2, 400.0).len()
        };
        assert!(count(12.0) > count(2.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut dvs = DvsSim::new(32, 32, 42);
            let mut scene = Scene::new(SceneKind::Corridor { speed_per_s: 1.0, seed: 9 });
            dvs.capture(&mut scene, 0.1, 200.0).events
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn reset_restores_power_on_state() {
        let seq = |dvs: &mut DvsSim| {
            let mut scene = Scene::new(SceneKind::Corridor { speed_per_s: 1.0, seed: 4 });
            dvs.capture(&mut scene, 0.1, 300.0).events
        };
        let mut fresh = DvsSim::new(32, 32, 8);
        fresh.noise_rate_hz = 50.0;
        let want = seq(&mut fresh);
        assert!(!want.is_empty());
        // drive the sensor hard on a different scene, then reset: the
        // replayed capture must match a fresh sensor event for event
        let mut reused = DvsSim::new(32, 32, 8);
        reused.noise_rate_hz = 50.0;
        let mut other = Scene::new(SceneKind::RotatingBar { omega_rad_s: 9.0 });
        reused.capture(&mut other, 0.05, 500.0);
        reused.reset();
        assert_eq!(seq(&mut reused), want);
    }

    #[test]
    fn step_into_appends_across_samples() {
        let mut a = DvsSim::new(32, 32, 6);
        let mut b = DvsSim::new(32, 32, 6);
        let scene = Scene::new(SceneKind::RotatingBar { omega_rad_s: 8.0 });
        let mut acc = EventWindow::new(32, 32);
        let mut want: Vec<Event> = Vec::new();
        for k in 0..20u64 {
            let t = k * 2_000_000;
            a.step_into(&scene, t, &mut acc);
            want.extend(b.step(&scene, t).events);
        }
        assert_eq!(acc.events, want);
    }

    #[test]
    fn scan_covers_chunks_and_tail_lanes() {
        // geometry chosen so the pixel count is NOT a lane multiple:
        // 13*5 = 65 = 8*8 + 1 — one full tail lane past the last chunk
        let n = 65usize;
        assert_ne!(n % DVS_LANES, 0);
        let lo = vec![0.25f32; n];
        let hi = vec![0.75f32; n];
        let mut img = vec![0.5f32; n];
        let mut out = Vec::new();
        scan_out_of_band(&img, &lo, &hi, &mut out);
        assert!(out.is_empty(), "all in-band must gather nothing");
        // mark out-of-band pixels across chunk boundaries and in the tail
        for &i in &[0usize, 7, 8, 31, 63, 64] {
            img[i] = 0.9;
        }
        scan_out_of_band(&img, &lo, &hi, &mut out);
        assert_eq!(out, vec![0u32, 7, 8, 31, 63, 64]);
        // band edges are exclusive: a pixel sitting exactly on an edge is
        // out of band, matching the scalar `>`/`<` predicate
        img.iter_mut().for_each(|v| *v = 0.5);
        img[3] = 0.25;
        img[64] = 0.75;
        scan_out_of_band(&img, &lo, &hi, &mut out);
        assert_eq!(out, vec![3u32, 64]);
    }

    #[test]
    fn vectorized_step_matches_scalar_reference() {
        // tail-heavy geometry (37*29 = 1073 ≡ 1 mod 8) + noise on: the
        // lane path must match the scalar loop event for event, band for
        // band, and leave the RNG at the same position
        for kind in [
            SceneKind::Corridor { speed_per_s: 0.8, seed: 3 },
            SceneKind::RotatingBar { omega_rad_s: 7.0 },
            SceneKind::Noise { density: 0.15, seed: 5 },
        ] {
            let mut vec_dvs = DvsSim::new(37, 29, 11);
            let mut sc_dvs = DvsSim::new(37, 29, 11);
            vec_dvs.noise_rate_hz = 120.0;
            sc_dvs.noise_rate_hz = 120.0;
            let mut scene_a = Scene::new(kind);
            let mut scene_b = Scene::new(kind);
            for k in 0..12u64 {
                let t = k * 1_700_000;
                scene_a.advance(t as f64 * 1e-9);
                scene_b.advance(t as f64 * 1e-9);
                let wa = vec_dvs.step(&scene_a, t);
                let wb = sc_dvs.step_scalar(&scene_b, t);
                assert_eq!(wa.events, wb.events, "{kind:?} step {k}");
            }
            assert_eq!(vec_dvs.band_state(), sc_dvs.band_state(), "{kind:?}");
            assert_eq!(vec_dvs.rng_probe(), sc_dvs.rng_probe(), "{kind:?}");
        }
    }
}
