//! SoC configuration: the Fig. 5 implementation table plus every calibration
//! constant of the energy/performance model, in one serializable struct.
//!
//! All anchors come from the paper's post-silicon measurements (§III):
//!
//! | anchor | value |
//! |---|---|
//! | SNE busy @0.8 V, 222 MHz | 98 mW; 20 800 inf/s @1 % activity; 1 019 @20 % |
//! | CUTIE busy @0.8 V, 330 MHz | 110 mW; >10 000 inf/s; 1 036 TOp/s/W peak |
//! | PULP busy @0.8 V, 330 MHz | 80 mW; DroNet 28 inf/s; 0.98 mac/cyc/core |
//! | SoC | VDD 0.5–0.8 V; 2 mW–300 mW; 330 MHz max; 1 MiB L2; 128 KiB L1 |
//!
//! `integration_calibration.rs` pins every anchor; if you touch a constant
//! here, that suite tells you which paper number you broke.


/// Supply voltage limits (V). The paper's FDX implementation spans
/// 0.5 V – 0.8 V with body biasing; we model the same range.
pub const VDD_MIN: f64 = 0.5;
pub const VDD_MAX: f64 = 0.8;

/// Alpha-power-law threshold voltage and exponent used for `f_max(V)`
/// scaling. Chosen so f(0.5 V)/f(0.8 V) ~= 0.36, typical for 22 nm FDX
/// logic without forward body bias.
pub const VT: f64 = 0.25;
pub const ALPHA: f64 = 1.3;

/// Retention power of the always-on SRAM macros (L2 state kept while the
/// engines are gated) — sets the ~2 mW deep-idle floor of Fig. 5.
pub const SRAM_RETENTION_W: f64 = 0.0015;

/// Frequency scaling factor relative to the 0.8 V maximum.
pub fn freq_scale(v: f64) -> f64 {
    ((v - VT).max(0.0) / (VDD_MAX - VT)).powf(ALPHA)
}

/// One clock/power domain's electrical parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct DomainCfg {
    /// Effective switched capacitance (F): P_dyn = c_eff * V^2 * f * u.
    pub c_eff: f64,
    /// Leakage coefficient (W/V): P_leak = leak_per_v * V when powered.
    pub leak_per_v: f64,
    /// Maximum clock frequency at VDD_MAX (Hz).
    pub f_max: f64,
    /// Fraction of busy dynamic power drawn when clocked but idle.
    pub idle_frac: f64,
}

impl DomainCfg {
    /// Maximum frequency at voltage `v`.
    pub fn f_at(&self, v: f64) -> f64 {
        self.f_max * freq_scale(v)
    }

    /// Dynamic power (W) at voltage `v`, frequency `f`, utilization `u`.
    pub fn p_dyn(&self, v: f64, f: f64, u: f64) -> f64 {
        let u_eff = self.idle_frac + (1.0 - self.idle_frac) * u.clamp(0.0, 1.0);
        self.c_eff * v * v * f * u_eff
    }

    /// Leakage power (W) at voltage `v`.
    pub fn p_leak(&self, v: f64) -> f64 {
        self.leak_per_v * v
    }
}

/// SNE micro-architecture + timing/energy calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct SneCfg {
    pub domain: DomainCfg,
    /// Number of engine slices (paper: 8, one 8 KiB LIF state memory each).
    pub slices: usize,
    /// LIF neuron state memory per slice (bytes).
    pub state_mem_per_slice: usize,
    /// Dedicated weight buffer (bytes) — 9.2 kB in silicon.
    pub weight_buf: usize,
    /// Synaptic operations retired per cycle per slice (dense burst mode).
    pub sops_per_cycle_per_slice: f64,
    /// Average cycles consumed per routed input event (COO decode +
    /// burst issue), fitted to the two Fig. 7 anchor points.
    pub cycles_per_event: f64,
    /// Fixed per-inference overhead cycles (config load, drain).
    pub fixed_cycles: f64,
    /// Weight precision (bits) — SNE supports 4-bit 3x3 kernels.
    pub w_bits: u32,
    /// Neuron state precision (bits).
    pub state_bits: u32,
}

/// CUTIE micro-architecture + calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct CutieCfg {
    pub domain: DomainCfg,
    /// Parallel output channels (paper: 96) — one output activation element
    /// per cycle per output channel.
    pub out_channels: usize,
    /// Kernel size the OCU array is unrolled for.
    pub ksize: usize,
    /// Feature-map memory (bytes) — 158 kB.
    pub fmap_mem: usize,
    /// Weight memory (bytes) — 117 kB at 1.6 b/weight compressed.
    pub weight_mem: usize,
    /// Pipeline fill + per-layer sequencing overhead (cycles).
    pub layer_overhead_cycles: f64,
    /// Compressed weight storage density (bits per ternary weight).
    pub bits_per_weight: f64,
}

impl CutieCfg {
    /// Ternary ops per cycle with the array fully utilized:
    /// out_channels * k^2 * in_channels(=out_channels) * 2 (mul+acc).
    pub fn peak_ops_per_cycle(&self) -> f64 {
        (self.out_channels * self.ksize * self.ksize * self.out_channels * 2) as f64
    }
}

/// Numeric precision modes of the PULP cluster (Fig. 4 x-axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
    Int4,
    Int2,
}

impl Precision {
    pub const ALL: [Precision; 5] = [
        Precision::Fp32,
        Precision::Fp16,
        Precision::Int8,
        Precision::Int4,
        Precision::Int2,
    ];

    pub fn bits(self) -> u32 {
        match self {
            Precision::Fp32 => 32,
            Precision::Fp16 => 16,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
            Precision::Int2 => 2,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Fp16 => "fp16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
            Precision::Int2 => "int2",
        }
    }
}

/// PULP cluster micro-architecture + calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct PulpCfg {
    pub domain: DomainCfg,
    /// Cores in the cluster (paper: 8).
    pub cores: usize,
    /// Shared L1 TCDM size (bytes) — 128 KiB.
    pub l1_bytes: usize,
    /// TCDM banks (word-interleaved); contention model input.
    pub l1_banks: usize,
    /// MACs per cycle per core for each precision (SIMD widening dotp).
    pub simd_macs_int8: f64,
    pub simd_macs_int4: f64,
    pub simd_macs_int2: f64,
    pub macs_fp32: f64,
    pub macs_fp16: f64,
    /// Inner-loop MAC issue efficiency with MAC-LD (paper: 0.98
    /// mac/cycle/core measured on conv patches).
    pub macld_efficiency: f64,
    /// End-to-end layer efficiency (im2col, DMA, tails) on full networks.
    pub net_efficiency: f64,
    /// Relative power of floating-point vs integer datapath activity.
    pub fp_power_factor: f64,
}

impl PulpCfg {
    /// MACs per cycle per core for `p`, before issue-efficiency derating.
    pub fn macs_per_cycle(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp32 => self.macs_fp32,
            Precision::Fp16 => self.macs_fp16,
            Precision::Int8 => self.simd_macs_int8,
            Precision::Int4 => self.simd_macs_int4,
            Precision::Int2 => self.simd_macs_int2,
        }
    }
}

/// Fabric controller + SoC interconnect/memory parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct FabricCfg {
    pub domain: DomainCfg,
    /// L2 scratchpad (bytes) — 1 MiB.
    pub l2_bytes: usize,
    /// L2 banks.
    pub l2_banks: usize,
    /// Interconnect beat width (bytes/cycle per port).
    pub bus_bytes_per_cycle: usize,
    /// DMA channels.
    pub dma_channels: usize,
    /// QSPI / I2C / UART / GPIO counts (Fig. 1 peripheral set).
    pub n_qspi: usize,
    pub n_i2c: usize,
    pub n_uart: usize,
    pub n_gpio: usize,
}

/// Complete SoC configuration (Fig. 5 + model calibration).
#[derive(Debug, Clone, PartialEq)]
pub struct SocConfig {
    pub name: String,
    pub technology: String,
    pub die_area_mm2: f64,
    pub vdd: f64,
    pub sne: SneCfg,
    pub cutie: CutieCfg,
    pub pulp: PulpCfg,
    pub fabric: FabricCfg,
}

impl SocConfig {
    /// The Kraken chip as measured (Fig. 5 + §III anchors).
    ///
    /// Calibration notes (worked derivations in DESIGN.md §4):
    /// * `c_eff` per domain from busy power at 0.8 V and the measured
    ///   clock: SNE 98 mW/222 MHz, CUTIE 110 mW/330 MHz, PULP 80 mW/330 MHz.
    /// * SNE `cycles_per_event` fitted so the two Fig. 7 anchors
    ///   (20 800 inf/s @1 %, 1 019 inf/s @20 %) fall out of the
    ///   LIF-FireNet event-traffic model in `nets::firenet_paper`.
    /// * Leakage coefficients chosen so peak efficiencies at the 0.5 V
    ///   best-efficiency point land on Fig. 6 (1 036 TOp/s/W CUTIE,
    ///   ~1.1 TSOP/s/W SNE, 1.8 TOp/s/W PULP int2).
    pub fn kraken() -> Self {
        SocConfig {
            name: "kraken".into(),
            technology: "GF 22 nm FDX (simulated)".into(),
            die_area_mm2: 9.0,
            vdd: VDD_MAX,
            sne: SneCfg {
                domain: DomainCfg {
                    // busy power at 0.8 V / 222 MHz = dyn + leak = 98 mW;
                    // the dyn/leak split is set so the 0.5 V best-efficiency
                    // point lands on ~1.1 TSOP/s/W (1.7x Tianjic, Fig. 6)
                    c_eff: 0.097653 / (0.64 * 222.0e6),
                    leak_per_v: 0.000434,
                    f_max: 222.0e6,
                    idle_frac: 0.05,
                },
                slices: 8,
                state_mem_per_slice: 8 * 1024,
                // "9.2 kB" in the paper; KiB-granular SRAM macro
                weight_buf: 9421,
                // 8 slices x 24 SOP/cycle = 192 SOP/cycle peak
                sops_per_cycle_per_slice: 24.0,
                // fitted to Fig. 7 (see integration_calibration.rs):
                // t(a) = a * E_max * cpe / f with E_max = 8.28e6 events
                // (132x128 FireNet, 5 timesteps) reproduces both measured
                // points (20 800 inf/s @1 %, 1 019 inf/s @20 %) within 1.1 %.
                cycles_per_event: 0.13021,
                fixed_cycles: 0.0,
                w_bits: 4,
                state_bits: 8,
            },
            cutie: CutieCfg {
                domain: DomainCfg {
                    // busy power at 0.8 V / 330 MHz = dyn + leak = 110 mW;
                    // split fitted so peak efficiency at 0.5 V = 1 036 TOp/s/W
                    c_eff: 0.102693 / (0.64 * 330.0e6),
                    leak_per_v: 0.009133,
                    f_max: 330.0e6,
                    idle_frac: 0.03,
                },
                out_channels: 96,
                ksize: 3,
                fmap_mem: 158_000,
                weight_mem: 117_000,
                layer_overhead_cycles: 96.0,
                bits_per_weight: 1.6,
            },
            pulp: PulpCfg {
                domain: DomainCfg {
                    // busy power at 0.8 V / 330 MHz = dyn + leak = 80 mW;
                    // split fitted so int2 peak at 0.5 V = 1.8 TOp/s/W
                    c_eff: 0.069090 / (0.64 * 330.0e6),
                    leak_per_v: 0.013638,
                    f_max: 330.0e6,
                    idle_frac: 0.08,
                },
                cores: 8,
                l1_bytes: 128 * 1024,
                l1_banks: 16,
                simd_macs_int8: 4.0,
                simd_macs_int4: 8.0,
                simd_macs_int2: 16.0,
                macs_fp32: 0.5,
                macs_fp16: 2.0,
                macld_efficiency: 0.98,
                // End-to-end fraction of SIMD peak sustained on a full
                // network (im2col marshalling, DMA, pooling, tails) —
                // calibrated so 8-bit DroNet (41 MMAC) runs at the measured
                // 28 inf/s at 330 MHz: 41.1e6 MACs / (330e6/28) cycles
                // = 3.49 MAC/cycle = 0.111 of the 31.4 MAC/cycle SIMD peak.
                net_efficiency: 0.1112,
                fp_power_factor: 1.2,
            },
            fabric: FabricCfg {
                domain: DomainCfg {
                    // FC + L2 + interconnect: ~10 mW @ 0.8 V, 330 MHz
                    c_eff: 0.010 / (0.64 * 330.0e6),
                    leak_per_v: 0.0008,
                    f_max: 330.0e6,
                    idle_frac: 0.25,
                },
                l2_bytes: 1024 * 1024,
                l2_banks: 8,
                bus_bytes_per_cycle: 8,
                dma_channels: 2,
                n_qspi: 4,
                n_i2c: 4,
                n_uart: 2,
                n_gpio: 48,
            },
        }
    }

    /// Load from a JSON file (the launcher's `--config` flag): start from
    /// the Kraken defaults and apply any overrides present in the file.
    /// Keys mirror the struct layout, e.g.
    /// `{"vdd": 0.65, "pulp": {"cores": 4}, "sne": {"slices": 4}}`.
    pub fn from_json_file(path: &str) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_text(&text)
    }

    /// Parse overrides from JSON text (see [`Self::from_json_file`]).
    pub fn from_json_text(text: &str) -> crate::Result<Self> {
        use crate::util::json::{parse, Value};
        let v = parse(text)?;
        let mut cfg = SocConfig::kraken();
        let num = |v: &Value, k: &str| v.get(k).and_then(Value::as_f64);
        let unum = |v: &Value, k: &str| v.get(k).and_then(Value::as_u64);
        if let Some(x) = v.get("name").and_then(Value::as_str) {
            cfg.name = x.to_string();
        }
        if let Some(x) = num(&v, "vdd") {
            cfg.vdd = x;
        }
        if let Some(x) = num(&v, "die_area_mm2") {
            cfg.die_area_mm2 = x;
        }
        let apply_domain = |d: &mut DomainCfg, o: &Value| {
            if let Some(x) = num(o, "c_eff") {
                d.c_eff = x;
            }
            if let Some(x) = num(o, "leak_per_v") {
                d.leak_per_v = x;
            }
            if let Some(x) = num(o, "f_max") {
                d.f_max = x;
            }
            if let Some(x) = num(o, "idle_frac") {
                d.idle_frac = x;
            }
        };
        if let Some(o) = v.get("sne") {
            if let Some(dd) = o.get("domain") {
                apply_domain(&mut cfg.sne.domain, dd);
            }
            if let Some(x) = unum(o, "slices") {
                cfg.sne.slices = x as usize;
            }
            if let Some(x) = num(o, "cycles_per_event") {
                cfg.sne.cycles_per_event = x;
            }
            if let Some(x) = num(o, "sops_per_cycle_per_slice") {
                cfg.sne.sops_per_cycle_per_slice = x;
            }
        }
        if let Some(o) = v.get("cutie") {
            if let Some(dd) = o.get("domain") {
                apply_domain(&mut cfg.cutie.domain, dd);
            }
            if let Some(x) = unum(o, "out_channels") {
                cfg.cutie.out_channels = x as usize;
            }
            if let Some(x) = num(o, "layer_overhead_cycles") {
                cfg.cutie.layer_overhead_cycles = x;
            }
        }
        if let Some(o) = v.get("pulp") {
            if let Some(dd) = o.get("domain") {
                apply_domain(&mut cfg.pulp.domain, dd);
            }
            if let Some(x) = unum(o, "cores") {
                cfg.pulp.cores = x as usize;
            }
            if let Some(x) = unum(o, "l1_banks") {
                cfg.pulp.l1_banks = x as usize;
            }
            if let Some(x) = num(o, "macld_efficiency") {
                cfg.pulp.macld_efficiency = x;
            }
            if let Some(x) = num(o, "net_efficiency") {
                cfg.pulp.net_efficiency = x;
            }
        }
        if let Some(o) = v.get("fabric") {
            if let Some(dd) = o.get("domain") {
                apply_domain(&mut cfg.fabric.domain, dd);
            }
            if let Some(x) = unum(o, "l2_bytes") {
                cfg.fabric.l2_bytes = x as usize;
            }
            if let Some(x) = unum(o, "dma_channels") {
                cfg.fabric.dma_channels = x as usize;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate physical consistency; called by `Soc::new`.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (VDD_MIN..=VDD_MAX).contains(&self.vdd),
            "vdd {} outside [{}, {}]",
            self.vdd,
            VDD_MIN,
            VDD_MAX
        );
        anyhow::ensure!(self.sne.slices > 0, "SNE needs at least one slice");
        anyhow::ensure!(self.pulp.cores > 0, "PULP needs at least one core");
        anyhow::ensure!(
            self.pulp.l1_banks >= self.pulp.cores,
            "TCDM banking below core count would serialize every access"
        );
        anyhow::ensure!(self.fabric.l2_bytes >= 64 * 1024, "L2 too small");
        for (name, d) in [
            ("sne", &self.sne.domain),
            ("cutie", &self.cutie.domain),
            ("pulp", &self.pulp.domain),
            ("fabric", &self.fabric.domain),
        ] {
            anyhow::ensure!(d.c_eff > 0.0, "{name}: c_eff must be positive");
            anyhow::ensure!(d.f_max > 0.0, "{name}: f_max must be positive");
            anyhow::ensure!(
                (0.0..=1.0).contains(&d.idle_frac),
                "{name}: idle_frac out of range"
            );
        }
        Ok(())
    }

    /// Total SoC leakage floor with every domain powered (W) — the paper's
    /// 2 mW minimum operating point corresponds to this at 0.5 V with all
    /// engines clock-gated.
    pub fn leakage_floor(&self, v: f64) -> f64 {
        [
            &self.sne.domain,
            &self.cutie.domain,
            &self.pulp.domain,
            &self.fabric.domain,
        ]
        .iter()
        .map(|d| d.p_leak(v))
        .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kraken_config_validates() {
        SocConfig::kraken().validate().unwrap();
    }

    #[test]
    fn freq_scaling_monotone_and_anchored() {
        assert!((freq_scale(VDD_MAX) - 1.0).abs() < 1e-12);
        let mut last = 0.0;
        for i in 0..=30 {
            let v = VDD_MIN + (VDD_MAX - VDD_MIN) * (i as f64) / 30.0;
            let s = freq_scale(v);
            assert!(s >= last, "freq_scale must be monotone");
            last = s;
        }
        // 0.5 V runs at roughly a third of the 0.8 V clock
        let s = freq_scale(0.5);
        assert!(s > 0.3 && s < 0.45, "freq_scale(0.5) = {s}");
    }

    #[test]
    fn busy_power_matches_measured_anchors() {
        let cfg = SocConfig::kraken();
        let busy = |d: &DomainCfg, f: f64| d.p_dyn(0.8, f, 1.0) + d.p_leak(0.8);
        let p_sne = busy(&cfg.sne.domain, 222.0e6);
        assert!((p_sne - 0.098).abs() / 0.098 < 1e-3, "SNE {p_sne}");
        let p_cutie = busy(&cfg.cutie.domain, 330.0e6);
        assert!((p_cutie - 0.110).abs() / 0.110 < 1e-3, "CUTIE {p_cutie}");
        let p_pulp = busy(&cfg.pulp.domain, 330.0e6);
        assert!((p_pulp - 0.080).abs() / 0.080 < 1e-3, "PULP {p_pulp}");
    }

    #[test]
    fn power_envelope_matches_fig5() {
        let cfg = SocConfig::kraken();
        // Max: all engines busy at 0.8 V plus fabric
        let max = cfg.sne.domain.p_dyn(0.8, 222.0e6, 1.0)
            + cfg.cutie.domain.p_dyn(0.8, 330.0e6, 1.0)
            + cfg.pulp.domain.p_dyn(0.8, 330.0e6, 1.0)
            + cfg.fabric.domain.p_dyn(0.8, 330.0e6, 1.0)
            + cfg.leakage_floor(0.8);
        assert!(max > 0.25 && max < 0.33, "max power {max} W vs paper 300 mW");
        // Min: engines gated (header switches kill their leakage), FC
        // clocked down, SRAM retention
        let min = cfg.fabric.domain.p_dyn(0.5, 100.0e6, 0.0)
            + cfg.fabric.domain.p_leak(0.5)
            + SRAM_RETENTION_W;
        assert!(min > 0.001 && min < 0.004, "min power {min} W vs paper 2 mW");
    }

    #[test]
    fn precision_table() {
        assert_eq!(Precision::Int4.bits(), 4);
        assert_eq!(Precision::ALL.len(), 5);
        let cfg = SocConfig::kraken();
        // SIMD doubling per precision halving below 8 bit
        assert_eq!(cfg.pulp.macs_per_cycle(Precision::Int4), 2.0 * cfg.pulp.macs_per_cycle(Precision::Int8));
        assert_eq!(cfg.pulp.macs_per_cycle(Precision::Int2), 4.0 * cfg.pulp.macs_per_cycle(Precision::Int8));
    }

    #[test]
    fn cutie_peak_ops() {
        let cfg = SocConfig::kraken();
        // 96 out-ch x 9 x 96 in-ch x 2 = 165 888 ternary ops/cycle
        assert_eq!(cfg.cutie.peak_ops_per_cycle(), 165_888.0);
    }

    #[test]
    fn json_overrides_apply() {
        let cfg = SocConfig::from_json_text(
            r#"{"vdd": 0.65, "pulp": {"cores": 4, "macld_efficiency": 0.9},
                "sne": {"slices": 4}, "name": "mini"}"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "mini");
        assert_eq!(cfg.vdd, 0.65);
        assert_eq!(cfg.pulp.cores, 4);
        assert_eq!(cfg.pulp.macld_efficiency, 0.9);
        assert_eq!(cfg.sne.slices, 4);
        // untouched fields keep silicon defaults
        assert_eq!(cfg.cutie.out_channels, 96);
    }

    #[test]
    fn json_overrides_validate() {
        // 2 banks for 8 cores violates the banking constraint
        assert!(SocConfig::from_json_text(r#"{"pulp": {"l1_banks": 2}}"#).is_err());
        assert!(SocConfig::from_json_text(r#"{"vdd": 1.2}"#).is_err());
    }
}
