//! The PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Adapted from the /opt/xla-example/load_hlo reference: text ->
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `client.compile`,
//! then `execute` with `Literal` inputs. All tensors are f32 (the AOT
//! contract — quantized values ride as exact small integers).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::runtime::manifest::{Manifest, TensorSpec};

/// A compiled artifact plus its manifest specs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The PJRT runtime: one CPU client + all compiled artifacts.
///
/// Not `Send` (PJRT client handles are thread-local by construction in the
/// xla crate); create it on the thread that will execute.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: HashMap<String, Executable>,
    pub manifest: Manifest,
    pub dir: PathBuf,
    /// Executions served (telemetry).
    pub calls: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load + compile every artifact in `dir` (verifying hashes).
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let names: Vec<String> = manifest.artifacts.keys().cloned().collect();
        Self::load_subset(dir, &names)
    }

    /// Load + compile a subset of artifacts (benches that only need one).
    pub fn load_subset(dir: &Path, names: &[String]) -> crate::Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut executables = HashMap::new();
        for name in names {
            let meta = manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?;
            manifest.verify_hash(dir, name)?;
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.insert(
                name.clone(),
                Executable { exe, inputs: meta.inputs.clone(), outputs: meta.outputs.clone() },
            );
        }
        Ok(Runtime {
            client,
            executables,
            manifest,
            dir: dir.to_path_buf(),
            calls: std::cell::Cell::new(0),
        })
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Input specs of an artifact (for buffer pre-allocation).
    pub fn input_specs(&self, name: &str) -> crate::Result<&[TensorSpec]> {
        Ok(&self.exe(name)?.inputs)
    }

    pub fn output_specs(&self, name: &str) -> crate::Result<&[TensorSpec]> {
        Ok(&self.exe(name)?.outputs)
    }

    fn exe(&self, name: &str) -> crate::Result<&Executable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not loaded"))
    }

    /// Execute artifact `name` on flat f32 inputs (one Vec per manifest
    /// input, C-order). Returns flat f32 outputs in manifest order.
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> crate::Result<Vec<Vec<f32>>> {
        let ex = self.exe(name)?;
        anyhow::ensure!(
            inputs.len() == ex.inputs.len(),
            "artifact '{name}': {} inputs given, {} expected",
            inputs.len(),
            ex.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&ex.inputs) {
            anyhow::ensure!(
                buf.len() == spec.elements(),
                "artifact '{name}' input '{}': {} elements given, {} expected",
                spec.name,
                buf.len(),
                spec.elements()
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
        }
        let result = ex.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple at top level.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == ex.outputs.len(),
            "artifact '{name}': {} outputs returned, {} in manifest",
            parts.len(),
            ex.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, spec) in parts.into_iter().zip(&ex.outputs) {
            let v = lit.to_vec::<f32>()?;
            anyhow::ensure!(
                v.len() == spec.elements(),
                "artifact '{name}' output '{}': {} elements, {} expected",
                spec.name,
                v.len(),
                spec.elements()
            );
            out.push(v);
        }
        self.calls.set(self.calls.get() + 1);
        Ok(out)
    }

    /// Convenience: zeroed input buffers shaped per the manifest.
    pub fn zero_inputs(&self, name: &str) -> crate::Result<Vec<Vec<f32>>> {
        Ok(self
            .exe(name)?
            .inputs
            .iter()
            .map(|s| vec![0f32; s.elements()])
            .collect())
    }
}

// Integration coverage for this module lives in
// rust/tests/integration_runtime.rs (requires `make artifacts`).
