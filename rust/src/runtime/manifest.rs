//! `artifacts/manifest.json`: the contract between the Python compile path
//! and the Rust runtime.
//!
//! aot.py writes, for every artifact, its file name, target engine, exact
//! input/output tensor specs, workload statistics, and a sha256 of the HLO
//! text. The runtime refuses to run artifacts whose hash or shapes drift
//! from the manifest — the same role a firmware image header plays.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub engine: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub stats: Value,
    pub sha256: String,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub seed: u64,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}. Run `make artifacts` first.", path.display()))?;
        let m = Self::from_json_text(&text)?;
        anyhow::ensure!(!m.artifacts.is_empty(), "manifest lists no artifacts");
        Ok(m)
    }

    /// Parse the manifest JSON document.
    pub fn from_json_text(text: &str) -> crate::Result<Self> {
        let v = json::parse(text)?;
        let seed = v
            .get("seed")
            .and_then(Value::as_u64)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing 'seed'"))?;
        let arts = v
            .get("artifacts")
            .and_then(Value::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing 'artifacts'"))?;
        let mut artifacts = HashMap::new();
        for (name, a) in arts {
            artifacts.insert(name.clone(), parse_artifact(name, a)?);
        }
        Ok(Manifest { seed, artifacts })
    }

    pub fn path_of(&self, dir: &Path, name: &str) -> crate::Result<PathBuf> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?;
        Ok(dir.join(&art.file))
    }

    /// Verify the sha256 of an artifact's HLO text against the manifest.
    pub fn verify_hash(&self, dir: &Path, name: &str) -> crate::Result<()> {
        let art = &self.artifacts[name];
        let text = std::fs::read_to_string(dir.join(&art.file))?;
        let got = sha256_hex(text.as_bytes());
        anyhow::ensure!(
            got == art.sha256,
            "artifact '{name}' hash mismatch: rebuild artifacts (make artifacts)"
        );
        Ok(())
    }

    /// Cross-check a manifest entry's MAC statistics against a Rust net
    /// descriptor (keeps the analytical and functional views in lock-step).
    pub fn check_stats_macs(&self, name: &str, want_total_macs: u64) -> crate::Result<()> {
        let art = &self.artifacts[name];
        let layers = art.stats.get("layers").and_then(Value::as_arr);
        if let Some(layers) = layers {
            let total: u64 = layers
                .iter()
                .filter_map(|l| l.get("macs").and_then(Value::as_u64))
                .sum();
            anyhow::ensure!(
                total == want_total_macs,
                "artifact '{name}': manifest MACs {total} != descriptor {want_total_macs}"
            );
        }
        Ok(())
    }
}

fn parse_tensor(t: &Value) -> crate::Result<TensorSpec> {
    let name = t
        .get("name")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow::anyhow!("tensor spec missing 'name'"))?
        .to_string();
    let shape: Vec<usize> = t
        .get("shape")
        .and_then(Value::as_arr)
        .ok_or_else(|| anyhow::anyhow!("tensor '{name}' missing 'shape'"))?
        .iter()
        .map(|d| d.as_u64().map(|d| d as usize))
        .collect::<Option<_>>()
        .ok_or_else(|| anyhow::anyhow!("tensor '{name}': bad shape"))?;
    let dtype = t
        .get("dtype")
        .and_then(Value::as_str)
        .unwrap_or("f32")
        .to_string();
    Ok(TensorSpec { name, shape, dtype })
}

fn parse_artifact(name: &str, a: &Value) -> crate::Result<ArtifactMeta> {
    let field = |k: &str| -> crate::Result<String> {
        Ok(a.get(k)
            .and_then(Value::as_str)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}': missing '{k}'"))?
            .to_string())
    };
    let tensors = |k: &str| -> crate::Result<Vec<TensorSpec>> {
        a.get(k)
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}': missing '{k}'"))?
            .iter()
            .map(parse_tensor)
            .collect()
    };
    Ok(ArtifactMeta {
        file: field("file")?,
        engine: field("engine")?,
        inputs: tensors("inputs")?,
        outputs: tensors("outputs")?,
        stats: a.get("stats").cloned().unwrap_or(Value::Null),
        sha256: field("sha256")?,
    })
}

/// Minimal SHA-256 (pure Rust, no deps) — used to pin artifact integrity.
pub fn sha256_hex(data: &[u8]) -> String {
    const K: [u32; 64] = [
        0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
        0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
        0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
        0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
        0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
        0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
        0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
        0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
        0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
        0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
        0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
    ];
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
        0x1f83d9ab, 0x5be0cd19,
    ];
    let mut msg = data.to_vec();
    let bitlen = (data.len() as u64) * 8;
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());
    for chunk in msg.chunks(64) {
        let mut w = [0u32; 64];
        for i in 0..16 {
            w[i] = u32::from_be_bytes(chunk[4 * i..4 * i + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let (mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh) =
            (h[0], h[1], h[2], h[3], h[4], h[5], h[6], h[7]);
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ ((!e) & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }
    h.iter().map(|x| format!("{x:08x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_known_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // multi-block message
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { name: "x".into(), shape: vec![2, 64, 64], dtype: "f32".into() };
        assert_eq!(t.elements(), 8192);
    }

    #[test]
    fn manifest_parses_real_schema() {
        let json = r#"{
            "seed": 12648430,
            "artifacts": {
                "firenet": {
                    "file": "firenet.hlo.txt",
                    "engine": "sne",
                    "inputs": [{"name": "events", "shape": [2, 64, 64], "dtype": "f32"}],
                    "outputs": [{"name": "flow", "shape": [2, 64, 64], "dtype": "f32"}],
                    "stats": {"layers": [{"macs": 100}, {"macs": 23}]},
                    "sha256": "00"
                }
            }
        }"#;
        let m = Manifest::from_json_text(json).unwrap();
        assert_eq!(m.artifacts["firenet"].engine, "sne");
        m.check_stats_macs("firenet", 123).unwrap();
        assert!(m.check_stats_macs("firenet", 124).is_err());
    }
}
