//! PJRT runtime: load the AOT artifacts Python emitted and execute them
//! from the Rust hot path. Python never runs here.
//!
//! * [`manifest`] — `artifacts/manifest.json` schema + integrity checks.
//! * [`executor`] — PJRT CPU client: HLO text -> compile -> execute, with
//!   f32 marshalling and per-artifact I/O validation.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! DESIGN.md): jax >= 0.5 serialized protos use 64-bit instruction ids the
//! bundled xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! `PjRtClient` is `Rc`-based (not `Send`): a [`executor::Runtime`] lives on
//! the thread that created it. The coordinator owns one and is itself a
//! single-threaded discrete-event simulation — exactly like the FC firmware
//! it models.

pub mod executor;
pub mod manifest;

pub use executor::Runtime;
pub use manifest::{ArtifactMeta, Manifest, TensorSpec};
