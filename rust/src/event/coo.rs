//! COO event lists and event windows.
//!
//! The paper: "SNE exploits an explicit coordinate list (COO) data
//! representation to efficiently transform unstructured spatio/temporal
//! sparse event computation [...] into SNE 'dense' computational bursts."
//!
//! [`Event`] is one DVS address-event (x, y, polarity, timestamp);
//! [`EventWindow`] is the unit of work the coordinator hands to the SNE
//! model: a time-sorted COO list plus helpers to bin it into the dense
//! per-timestep polarity maps the AOT FireNet artifact consumes.


/// DVS event polarity: brightness increase (On) or decrease (Off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Polarity {
    On,
    Off,
}

impl Polarity {
    /// Channel index in the 2-channel dense event tensor.
    pub fn channel(self) -> usize {
        match self {
            Polarity::On => 0,
            Polarity::Off => 1,
        }
    }
}

/// One address-event in COO form. 16-bit coordinates cover any DVS the SoC
/// interfaces (DVS132S is 132x128); timestamps are nanoseconds of simulated
/// mission time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub t_ns: u64,
    pub x: u16,
    pub y: u16,
    pub polarity: Polarity,
}

/// A time-ordered batch of events over a fixed sensor geometry.
#[derive(Debug, Clone, Default)]
pub struct EventWindow {
    pub width: usize,
    pub height: usize,
    pub events: Vec<Event>,
}

impl EventWindow {
    pub fn new(width: usize, height: usize) -> Self {
        EventWindow { width, height, events: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Push an event, keeping the window time-sorted (debug-asserted; the
    /// DVS simulator emits in order, the AER peripheral preserves it).
    pub fn push(&mut self, e: Event) {
        debug_assert!(
            self.events.last().map_or(true, |last| last.t_ns <= e.t_ns),
            "events must arrive time-sorted"
        );
        debug_assert!((e.x as usize) < self.width && (e.y as usize) < self.height);
        self.events.push(e);
    }

    /// Time span covered (ns); 0 for empty/single-event windows.
    pub fn span_ns(&self) -> u64 {
        match (self.events.first(), self.events.last()) {
            (Some(a), Some(b)) => b.t_ns - a.t_ns,
            _ => 0,
        }
    }

    /// Mean event activity: events per pixel over the window — the x-axis
    /// of Fig. 7. One full frame of events at both polarities would be 2.0.
    pub fn activity(&self) -> f64 {
        self.events.len() as f64 / (self.width * self.height) as f64
    }

    /// Bin into `t_bins` dense tensors of shape (2, height, width),
    /// flattened C-order, counting events per (polarity, pixel, bin).
    /// This is the dense-burst transform: the output feeds the FireNet
    /// artifact one bin at a time.
    pub fn bin(&self, t_bins: usize) -> Vec<Vec<f32>> {
        assert!(t_bins > 0);
        let plane = self.width * self.height;
        let mut out = vec![vec![0f32; 2 * plane]; t_bins];
        if self.events.is_empty() {
            return out;
        }
        let t0 = self.events.first().unwrap().t_ns;
        let span = self.span_ns().max(1);
        for e in &self.events {
            // last bin is inclusive of the window end
            let b = (((e.t_ns - t0) as u128 * t_bins as u128) / (span as u128 + 1))
                as usize;
            let idx = e.polarity.channel() * plane
                + e.y as usize * self.width
                + e.x as usize;
            out[b][idx] += 1.0;
        }
        out
    }

    /// Split into consecutive sub-windows of `dt_ns`; used by the
    /// coordinator to chop the AER stream into inference-sized chunks.
    pub fn split_by_time(&self, dt_ns: u64) -> Vec<EventWindow> {
        assert!(dt_ns > 0);
        let mut out: Vec<EventWindow> = Vec::new();
        if self.events.is_empty() {
            return out;
        }
        let t0 = self.events.first().unwrap().t_ns;
        for e in &self.events {
            let k = ((e.t_ns - t0) / dt_ns) as usize;
            while out.len() <= k {
                out.push(EventWindow::new(self.width, self.height));
            }
            out[k].push(*e);
        }
        out
    }

    /// Per-polarity event counts (on, off).
    pub fn polarity_counts(&self) -> (usize, usize) {
        let on = self
            .events
            .iter()
            .filter(|e| e.polarity == Polarity::On)
            .count();
        (on, self.events.len() - on)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: u64, x: u16, y: u16, p: Polarity) -> Event {
        Event { t_ns: t, x, y, polarity: p }
    }

    #[test]
    fn activity_counts_events_per_pixel() {
        let mut w = EventWindow::new(10, 10);
        for i in 0..50 {
            w.push(ev(i, (i % 10) as u16, (i / 10) as u16, Polarity::On));
        }
        assert!((w.activity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn binning_conserves_events() {
        let mut w = EventWindow::new(8, 8);
        for i in 0..100u64 {
            let p = if i % 3 == 0 { Polarity::Off } else { Polarity::On };
            w.push(ev(i * 37, (i % 8) as u16, ((i / 8) % 8) as u16, p));
        }
        for t_bins in [1usize, 2, 5, 16] {
            let bins = w.bin(t_bins);
            let total: f32 = bins.iter().flat_map(|b| b.iter()).sum();
            assert_eq!(total as usize, 100, "t_bins={t_bins}");
        }
    }

    #[test]
    fn binning_respects_polarity_channels() {
        let mut w = EventWindow::new(4, 4);
        w.push(ev(0, 1, 2, Polarity::On));
        w.push(ev(1, 3, 0, Polarity::Off));
        let bins = w.bin(1);
        let plane = 16;
        assert_eq!(bins[0][2 * 4 + 1], 1.0); // on-channel
        assert_eq!(bins[0][plane + 3], 1.0); // off-channel
    }

    #[test]
    fn binning_is_time_ordered() {
        let mut w = EventWindow::new(2, 2);
        w.push(ev(0, 0, 0, Polarity::On));
        w.push(ev(1000, 1, 1, Polarity::On));
        let bins = w.bin(2);
        assert_eq!(bins[0][0], 1.0);
        assert_eq!(bins[1][3], 1.0);
    }

    #[test]
    fn split_by_time_partitions() {
        let mut w = EventWindow::new(4, 4);
        for i in 0..30u64 {
            w.push(ev(i * 100, 0, 0, Polarity::On));
        }
        let parts = w.split_by_time(1000);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 30);
        assert!(parts.len() == 3);
        for p in &parts {
            assert!(p.span_ns() < 1000);
        }
    }

    #[test]
    fn empty_window() {
        let w = EventWindow::new(4, 4);
        assert_eq!(w.activity(), 0.0);
        assert_eq!(w.span_ns(), 0);
        let bins = w.bin(4);
        assert!(bins.iter().all(|b| b.iter().all(|&v| v == 0.0)));
    }
}
