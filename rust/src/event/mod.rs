//! Event-domain data structures: the explicit coordinate-list (COO)
//! representation SNE uses to turn unstructured spatio-temporal sparsity
//! into dense computational bursts.

pub mod coo;

pub use coo::{Event, EventWindow, Polarity};
