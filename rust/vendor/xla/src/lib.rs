//! Offline stub of the `xla` PJRT bindings.
//!
//! The container this repo builds in has no `xla_extension` shared library
//! and no network to fetch one, so the real crate cannot link. This stub
//! mirrors the exact API surface `kraken::runtime::executor` uses and fails
//! *at runtime* when a PJRT client is requested, which the coordinator
//! already handles: with no `artifacts/` directory present, missions run
//! analytical-only and never construct a client.
//!
//! Swapping this path dependency for the real `xla` crate re-enables the
//! functional artifact path with no call-site changes (DESIGN.md §6).

use std::fmt;

const UNAVAILABLE: &str = "PJRT backend unavailable: built against the offline xla stub \
     (vendor/xla); functional artifact execution is disabled in this environment";

/// Error type matching the shape of the real crate's.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be read back as.
pub trait NativeType: Copy {}
impl NativeType for f32 {}

/// A host-side tensor handle.
#[derive(Debug, Clone)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from host data.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _priv: () }
    }

    /// Reinterpret with a new shape.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    /// Decompose a top-level tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable())
    }

    /// Copy the literal's elements out to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// A computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A device-resident output buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute on one replica; outputs indexed `[replica][output]`.
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// A PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT backend unavailable"));
    }

    #[test]
    fn literal_construction_is_cheap_but_execution_fails() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
