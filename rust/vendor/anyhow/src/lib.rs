//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build is fully offline (no registry access), so the subset of
//! `anyhow` this crate actually uses is vendored here with the same API:
//!
//! * [`Error`] — an erased error with an optional source chain,
//! * [`Result`] — `Result<T, Error>` with the error type defaulted,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros,
//! * `impl From<E> for Error` for any `std::error::Error` (so `?` works).
//!
//! `{:#}` formatting prints the full cause chain like upstream anyhow.
//! Replacing this with the real crate is a one-line Cargo.toml change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An erased error: a message plus an optional boxed source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<M: fmt::Display>(self, message: M) -> Self {
        Error { msg: format!("{message}: {}", self.msg), source: self.source }
    }

    /// Iterate the cause chain (deepest last), starting at this error.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: self.source.as_ref().map(|b| &**b as &(dyn StdError + 'static)) }
    }
}

/// Iterator over an [`Error`]'s source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion legal.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            // the boxed source's own Display is already `self.msg`; start
            // the printed chain one level below it
            let mut cur = self
                .source
                .as_ref()
                .and_then(|b| (&**b as &(dyn StdError + 'static)).source());
            while let Some(e) = cur {
                write!(f, ": {e}")?;
                cur = e.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<_> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for (i, e) in causes.iter().enumerate() {
                write!(f, "\n    {i}: {e}")?;
            }
        }
        Ok(())
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn macros_build_messages() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(inner(7).unwrap_err().to_string(), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn alternate_format_prints_chain() {
        let e = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest: missing file");
    }

    #[test]
    fn chain_walks_sources() {
        let e = Error::from(io_err());
        assert_eq!(e.chain().count(), 1);
        assert!(Error::msg("no source").chain().next().is_none());
    }
}
