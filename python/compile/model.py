"""L2: JAX forward models for Kraken's three engines (+ gesture benchmark).

Four networks, each mapped to the engine that runs it on the SoC:

  * ``firenet_step``  — LIF-FireNet optical flow (SNE). One timestep; the
    Rust coordinator owns the recurrence, mirroring how SNE keeps neuron
    state resident in its SRAM banks between event bursts.
  * ``cutie_forward`` — 7-layer, 96-wide ternary CNN (CUTIE).
  * ``dronet_forward``— 8-bit quantized DroNet: steering + collision (PULP).
  * ``gesture_step``  — 6-layer CSNN classifier (SNE accuracy benchmark,
    IBM DVS-Gesture-like).

Every compute hot spot routes through the L1 Pallas kernels
(kernels.lif / kernels.ternary_conv / kernels.conv_int8); everything else is
plain jnp so XLA fuses it around the kernels. All functions are pure and
jittable; aot.py closes them over deterministic parameters and lowers them
to HLO text artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import CutieCfg, DroNetCfg, FireNetCfg, GestureCfg, SEED
from .kernels import conv_int8, lif, ref, ternary_conv


# ---------------------------------------------------------------------------
# Parameter initialisation (deterministic, quantized)
# ---------------------------------------------------------------------------

def _conv_init(key, c_out, c_in, k):
    w = jax.random.normal(key, (c_out, c_in, k, k)) / jnp.sqrt(c_in * k * k)
    return w


def _quantize_w(w, n_bits):
    """Quantize weights to signed n_bits integer grid, return integer-valued
    f32 tensor and scale (mirrors SNE's 4-bit / PULP's 8-bit storage)."""
    q, scale = ref.quantize_sym(w, n_bits)
    return q, scale


def init_firenet(cfg: FireNetCfg, seed: int = SEED):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(cfg.hidden) + 1)
    chans = (cfg.in_ch,) + cfg.hidden
    layers = []
    for i in range(len(cfg.hidden)):
        w = _conv_init(keys[i], chans[i + 1], chans[i], cfg.ksize)
        q, scale = _quantize_w(w, cfg.w_bits)
        # fold the quant scale into the layer so currents stay O(1)
        layers.append({"w": q, "scale": scale})
    w_head = _conv_init(keys[-1], cfg.flow_ch, cfg.hidden[-1], cfg.ksize)
    return {"layers": layers, "head": w_head}


def init_cutie(cfg: CutieCfg, seed: int = SEED + 1):
    keys = jax.random.split(jax.random.PRNGKey(seed), cfg.n_layers + 1)
    chans = (cfg.in_ch,) + (cfg.width,) * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        w = _conv_init(keys[i], chans[i + 1], chans[i], cfg.ksize)
        wt = ref.ternarize(w, 0.05 / jnp.sqrt(chans[i]))
        # per-channel symmetric firing thresholds, scaled to fan-in
        fan_in = chans[i] * cfg.ksize**2
        thr = 0.08 * fan_in * jnp.abs(
            jax.random.normal(jax.random.fold_in(keys[i], 7), (cfg.width,))
        ) / jnp.sqrt(fan_in)
        layers.append({"w": wt, "thr_lo": -thr, "thr_hi": thr})
    w_fc = jax.random.normal(keys[-1], (cfg.width, cfg.n_classes)) / jnp.sqrt(
        cfg.width
    )
    return {"layers": layers, "fc": ref.ternarize(w_fc, 0.02)}


def init_dronet(cfg: DroNetCfg, seed: int = SEED + 2):
    keys = jax.random.split(jax.random.PRNGKey(seed), 16)
    ki = iter(keys)
    params = {}
    params["stem"], _ = _quantize_w(_conv_init(next(ki), cfg.stem_ch, cfg.in_ch, 5), 8)
    chans = (cfg.stem_ch,) + cfg.block_ch
    blocks = []
    for i in range(len(cfg.block_ch)):
        b = {
            "conv1": _quantize_w(_conv_init(next(ki), chans[i + 1], chans[i], 3), 8)[0],
            "conv2": _quantize_w(_conv_init(next(ki), chans[i + 1], chans[i + 1], 3), 8)[0],
            "skip": _quantize_w(_conv_init(next(ki), chans[i + 1], chans[i], 1), 8)[0],
        }
        blocks.append(b)
    params["blocks"] = blocks
    params["w_steer"] = jax.random.normal(next(ki), (cfg.block_ch[-1], 1)) * 0.05
    params["w_coll"] = jax.random.normal(next(ki), (cfg.block_ch[-1], 1)) * 0.05
    return params


def init_gesture(cfg: GestureCfg, seed: int = SEED + 3):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(cfg.channels) + 1)
    chans = (cfg.in_ch,) + cfg.channels
    layers = []
    for i in range(len(cfg.channels)):
        w = _conv_init(keys[i], chans[i + 1], chans[i], 3)
        q, scale = _quantize_w(w, 4)
        layers.append({"w": q, "scale": scale})
    w_fc = jax.random.normal(keys[-1], (cfg.channels[-1], cfg.n_classes)) / jnp.sqrt(
        cfg.channels[-1]
    )
    return {"layers": layers, "fc": w_fc}


# ---------------------------------------------------------------------------
# FireNet (SNE): one recurrent timestep
# ---------------------------------------------------------------------------

def firenet_step(params, cfg: FireNetCfg, x, states, *, interpret=True):
    """One FireNet timestep.

    Args:
      x: (in_ch, H, W) binned event counts for this timestep (f32).
      states: list of 4 membrane tensors, shapes cfg.state_shapes.

    Returns:
      flow: (2, H, W) per-pixel optical flow.
      new_states: updated membranes.
      spike_counts: (n_layers,) total spikes per hidden layer — fed back to
        the Rust SNE energy model (energy proportionality, Fig 7).
    """
    spikes = x
    new_states = []
    counts = []
    for layer, v in zip(params["layers"], states):
        cur = ref.conv2d(spikes, layer["w"] * layer["scale"])
        v_next, s = lif.lif_update(v, cur, cfg.decay, cfg.v_th, interpret=interpret)
        new_states.append(v_next)
        counts.append(jnp.sum(s))
        spikes = s
    flow = ref.conv2d(spikes, params["head"])
    return flow, new_states, jnp.stack(counts)


def firenet_rollout(params, cfg: FireNetCfg, x_seq, states, *, interpret=True):
    """T-step scan rollout (training/tests); states threaded via lax.scan."""

    def step(carry, x):
        flow, new_states, counts = firenet_step(
            params, cfg, x, carry, interpret=interpret
        )
        return new_states, (flow, counts)

    final_states, (flows, counts) = jax.lax.scan(step, list(states), x_seq)
    return flows, final_states, counts


# ---------------------------------------------------------------------------
# CUTIE: ternary CNN forward
# ---------------------------------------------------------------------------

def _tconv(x, layer, cfg, *, interpret=True):
    patches = ref.im2col(x, cfg.ksize, cfg.ksize)
    c_out = layer["w"].shape[0]
    w_mat = layer["w"].reshape(c_out, -1).T  # (K, N) — K = C_in*k*k
    # im2col emits K ordered as (c, kh*kw); weight reshape (C_out, C_in, k, k)
    # flattens the same way, so the two agree.
    y = ternary_conv.ternary_gemm(
        patches, w_mat, layer["thr_lo"], layer["thr_hi"], interpret=interpret
    )
    h = x.shape[1]
    return y.T.reshape(c_out, h, x.shape[2])


def cutie_forward(params, cfg: CutieCfg, x, *, interpret=True):
    """Ternary CNN forward. x: (in_ch, S, S) in {-1,0,+1}.

    Returns (logits, nonzero_fraction) — the latter drives nothing on CUTIE
    (its datapath is dense/activity-independent) but is logged for analysis.
    """
    act = x
    nz = []
    for i, layer in enumerate(params["layers"]):
        act = _tconv(act, layer, cfg, interpret=interpret)
        nz.append(jnp.mean(jnp.abs(act)))
        if (i + 1) in cfg.pool_after:
            act = ref.maxpool2(act)
    pooled = ref.avgpool_global(act)
    logits = pooled @ params["fc"]
    return logits, jnp.stack(nz)


# ---------------------------------------------------------------------------
# DroNet (PULP): int8 residual network, two heads
# ---------------------------------------------------------------------------

def _iconv(x, w, cfg, stride=1, *, relu=True, interpret=True):
    k = w.shape[-1]
    patches = ref.im2col(x, k, k, stride=stride)
    c_out = w.shape[0]
    w_mat = w.reshape(c_out, -1).T
    y = conv_int8.int8_gemm(patches, w_mat, cfg.acc_shift, interpret=interpret)
    h_out = (x.shape[1] + stride - 1) // stride
    w_out = (x.shape[2] + stride - 1) // stride
    y = y.T.reshape(c_out, h_out, w_out)
    if relu:
        y = jnp.clip(y, 0.0, 127.0)
    return y


def dronet_forward(params, cfg: DroNetCfg, x, *, interpret=True):
    """8-bit DroNet. x: (1, S, S) int8-valued f32 (centered luma).

    Returns (steer, collision_logit) as a (2,) vector.
    """
    act = _iconv(x, params["stem"], cfg, stride=2, interpret=interpret)
    act = ref.maxpool2(act)
    for b in params["blocks"]:
        y = _iconv(act, b["conv1"], cfg, stride=2, interpret=interpret)
        y = _iconv(y, b["conv2"], cfg, relu=False, interpret=interpret)
        skip = _iconv(act, b["skip"], cfg, stride=2, relu=False, interpret=interpret)
        act = jnp.clip(y + skip, 0.0, 127.0)
    feat = ref.avgpool_global(act) / 128.0
    steer = feat @ params["w_steer"][:, 0]
    coll = feat @ params["w_coll"][:, 0]
    return jnp.stack([steer, coll])


# ---------------------------------------------------------------------------
# Gesture CSNN (SNE accuracy benchmark)
# ---------------------------------------------------------------------------

def gesture_step(params, cfg: GestureCfg, x, states, acc, *, interpret=True):
    """One timestep of the 6-layer gesture classifier.

    Args:
      x: (in_ch, S, S) binned events.
      states: 5 membrane tensors (one per conv layer, post-pool shapes).
      acc: (n_classes,) accumulated readout membrane.

    Returns (new_states, new_acc, spike_counts).
    """
    spikes = x
    new_states, counts = [], []
    for i, (layer, v) in enumerate(zip(params["layers"], states)):
        cur = ref.conv2d(spikes, layer["w"] * layer["scale"])
        v_next, s = lif.lif_update(v, cur, cfg.decay, cfg.v_th, interpret=interpret)
        new_states.append(v_next)
        counts.append(jnp.sum(s))
        spikes = s
        if (i + 1) in cfg.pool_after:
            spikes = ref.maxpool2(spikes)
    feat = ref.avgpool_global(spikes)
    new_acc = acc + feat @ params["fc"]
    return new_states, new_acc, jnp.stack(counts)


def gesture_state_shapes(cfg: GestureCfg):
    """Membrane shapes per conv layer, accounting for pooling of inputs."""
    shapes = []
    s = cfg.in_size
    for i, c in enumerate(cfg.channels):
        shapes.append((c, s, s))
        if (i + 1) in cfg.pool_after:
            s //= 2
    return shapes


def gesture_rollout(params, cfg: GestureCfg, x_seq, *, interpret=True):
    """Full T-step classification: returns logits after cfg.timesteps."""
    states = [jnp.zeros(s) for s in gesture_state_shapes(cfg)]
    acc = jnp.zeros((cfg.n_classes,))

    def step(carry, x):
        states, acc = carry
        states, acc, counts = gesture_step(
            params, cfg, x, states, acc, interpret=interpret
        )
        return (states, acc), counts

    (states, acc), counts = jax.lax.scan(step, (states, acc), x_seq)
    return acc, counts


# ---------------------------------------------------------------------------
# Workload statistics (consumed by aot.py for the manifest; Rust cross-checks
# its nets/ descriptors against these numbers)
# ---------------------------------------------------------------------------

def firenet_stats(cfg: FireNetCfg):
    chans = (cfg.in_ch,) + cfg.hidden
    hw = cfg.height * cfg.width
    layers = []
    for i in range(len(cfg.hidden)):
        layers.append(
            {
                "c_in": chans[i],
                "c_out": chans[i + 1],
                "h": cfg.height,
                "w": cfg.width,
                "macs": hw * chans[i] * chans[i + 1] * cfg.ksize**2,
                "neurons": hw * chans[i + 1],
            }
        )
    layers.append(
        {
            "c_in": cfg.hidden[-1],
            "c_out": cfg.flow_ch,
            "h": cfg.height,
            "w": cfg.width,
            "macs": hw * cfg.hidden[-1] * cfg.flow_ch * cfg.ksize**2,
            "neurons": 0,
        }
    )
    return {"layers": layers, "total_neurons": sum(l["neurons"] for l in layers)}


def cutie_stats(cfg: CutieCfg):
    chans = (cfg.in_ch,) + (cfg.width,) * cfg.n_layers
    s = cfg.in_size
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                "c_in": chans[i],
                "c_out": chans[i + 1],
                "h": s,
                "w": s,
                "out_pixels": s * s,
                "macs": s * s * chans[i] * chans[i + 1] * cfg.ksize**2,
            }
        )
        if (i + 1) in cfg.pool_after:
            s //= 2
    return {
        "layers": layers,
        "total_out_pixels": sum(l["out_pixels"] for l in layers),
        "total_macs": sum(l["macs"] for l in layers),
    }


def dronet_stats(cfg: DroNetCfg):
    s = cfg.in_size
    layers = []
    s2 = s // 2  # stem stride 2
    layers.append({"c_in": cfg.in_ch, "c_out": cfg.stem_ch, "h": s2, "w": s2,
                   "macs": s2 * s2 * cfg.in_ch * cfg.stem_ch * 25})
    s2 //= 2  # maxpool
    chans = (cfg.stem_ch,) + cfg.block_ch
    for i in range(len(cfg.block_ch)):
        so = s2 // 2
        layers.append({"c_in": chans[i], "c_out": chans[i + 1], "h": so, "w": so,
                       "macs": so * so * chans[i] * chans[i + 1] * 9})
        layers.append({"c_in": chans[i + 1], "c_out": chans[i + 1], "h": so,
                       "w": so, "macs": so * so * chans[i + 1] ** 2 * 9})
        layers.append({"c_in": chans[i], "c_out": chans[i + 1], "h": so, "w": so,
                       "macs": so * so * chans[i] * chans[i + 1]})
        s2 = so
    return {"layers": layers, "total_macs": sum(l["macs"] for l in layers)}
