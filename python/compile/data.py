"""Synthetic datasets for the build-time trainer and the pytest suite.

Substitutions for the paper's datasets (documented in DESIGN.md §1):

  * CIFAR10 (CUTIE)          -> 10-class procedural shape images, 32x32x3.
  * IBM DVS-Gesture (SNE)    -> 11-class synthetic event gestures: rotating
                                bars, translating edges, expanding blobs.
  * Himax corridor imagery   -> 96x96 corridor renders with a heading line
    (DroNet / PULP)             and optional obstacle; labels = (steer, coll).

The same generative models are implemented in rust/src/sensors/ so the Rust
end-to-end driver feeds the engines statistically identical inputs.
"""

from __future__ import annotations

import numpy as np


def _grid(size):
    y, x = np.mgrid[0:size, 0:size].astype(np.float32)
    c = (size - 1) / 2.0
    return (x - c) / size, (y - c) / size


# ---------------------------------------------------------------------------
# 10-class shape images (CIFAR10 stand-in for CUTIE)
# ---------------------------------------------------------------------------

def shape_image(cls: int, rng: np.random.Generator, size: int = 32):
    """Render one 3-channel image of shape-class ``cls`` in [0, 10)."""
    x, y = _grid(size)
    jx, jy = rng.uniform(-0.1, 0.1, 2)
    x, y = x - jx, y - jy
    r = np.sqrt(x**2 + y**2)
    ang = np.arctan2(y, x)
    s = rng.uniform(0.18, 0.3)
    masks = [
        r < s,                                     # 0 disk
        (np.abs(x) < s) & (np.abs(y) < s),         # 1 square
        np.abs(x + y) < 0.08,                      # 2 diagonal stripe
        np.abs(x - y) < 0.08,                      # 3 anti-diagonal stripe
        (r < s) & (r > s * 0.55),                  # 4 ring
        np.abs(np.sin(x * 18)) > 0.82,             # 5 vertical grating
        np.abs(np.sin(y * 18)) > 0.82,             # 6 horizontal grating
        (np.abs(x) < 0.06) | (np.abs(y) < 0.06),   # 7 cross
        (y > -s) & (y < s) & (np.abs(x) < (y + s) * 0.6),  # 8 triangle
        np.cos(ang * 5 + rng.uniform(0, 6.28)) * (r < 0.42) > 0.45,  # 9 star
    ]
    m = masks[cls].astype(np.float32)
    img = np.stack(
        [
            m * rng.uniform(0.6, 1.0) + rng.normal(0, 0.12, (size, size)),
            m * rng.uniform(0.2, 0.8) + rng.normal(0, 0.12, (size, size)),
            (1 - m) * rng.uniform(0.2, 0.6) + rng.normal(0, 0.12, (size, size)),
        ]
    ).astype(np.float32)
    return img


def shape_dataset(n: int, seed: int = 0, size: int = 32):
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 3, size, size), np.float32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        cls = int(rng.integers(0, 10))
        xs[i] = shape_image(cls, rng, size)
        ys[i] = cls
    return xs, ys


def ternarize_images(xs, thr: float = 0.25):
    """Center + ternarize a batch of images to {-1,0,+1} (CUTIE input)."""
    xs = xs - xs.mean(axis=(2, 3), keepdims=True)
    return np.where(xs > thr, 1.0, np.where(xs < -thr, -1.0, 0.0)).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# 11-class event gestures (DVS-Gesture stand-in for SNE)
# ---------------------------------------------------------------------------

GESTURE_NAMES = [
    "rotate_cw", "rotate_ccw", "rotate_cw_fast", "rotate_ccw_fast",
    "slide_left", "slide_right", "slide_up", "slide_down",
    "expand", "contract", "flicker",
]


def gesture_frames(cls: int, t_steps: int, rng: np.random.Generator,
                   size: int = 32):
    """Intensity frames for gesture ``cls``; events = temporal derivative."""
    x, y = _grid(size)
    frames = np.zeros((t_steps + 1, size, size), np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    speed = rng.uniform(0.85, 1.15)
    for t in range(t_steps + 1):
        tt = t * speed
        if cls in (0, 1, 2, 3):
            w = (0.25 if cls < 2 else 0.55) * (1 if cls % 2 == 0 else -1)
            ang = phase + w * tt
            d = np.abs(x * np.sin(ang) - y * np.cos(ang))
            frames[t] = ((d < 0.07) & (x**2 + y**2 < 0.2)).astype(np.float32)
        elif cls in (4, 5, 6, 7):
            off = 0.08 * tt * (1 if cls in (5, 7) else -1) + phase / 10
            off = ((off + 0.5) % 1.0) - 0.5
            d = x - off if cls in (4, 5) else y - off
            frames[t] = (np.abs(d) < 0.06).astype(np.float32)
        elif cls in (8, 9):
            r0 = 0.05 + 0.03 * (tt if cls == 8 else (t_steps - tt))
            r = np.sqrt(x**2 + y**2)
            frames[t] = ((r < r0) & (r > r0 - 0.08)).astype(np.float32)
        else:  # flicker
            frames[t] = float(t % 2) * ((x**2 + y**2) < 0.15)
    return frames


def gesture_events(cls: int, t_steps: int, seed: int = 0, size: int = 32,
                   noise: float = 0.01):
    """Event bins (t_steps, 2, size, size): ON/OFF polarities + noise."""
    rng = np.random.default_rng(seed)
    frames = gesture_frames(cls, t_steps, rng, size)
    diff = np.diff(frames, axis=0)
    ev = np.zeros((t_steps, 2, size, size), np.float32)
    ev[:, 0] = (diff > 0.5).astype(np.float32)
    ev[:, 1] = (diff < -0.5).astype(np.float32)
    ev += (rng.random(ev.shape) < noise).astype(np.float32)
    return np.clip(ev, 0.0, 1.0)


def gesture_dataset(n: int, t_steps: int = 16, seed: int = 0, size: int = 32):
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, t_steps, 2, size, size), np.float32)
    ys = np.zeros((n,), np.int32)
    for i in range(n):
        cls = int(rng.integers(0, 11))
        xs[i] = gesture_events(cls, t_steps, seed=int(rng.integers(1 << 30)),
                               size=size)
        ys[i] = cls
    return xs, ys


# ---------------------------------------------------------------------------
# Corridor images (DroNet stand-in for PULP)
# ---------------------------------------------------------------------------

def corridor_image(rng: np.random.Generator, size: int = 96):
    """96x96 luma with a heading line; labels: steer angle, collision flag."""
    x, y = _grid(size)
    steer = rng.uniform(-0.8, 0.8)
    d = np.abs(x - steer * (y + 0.5))
    img = np.exp(-(d**2) / 0.01) * 80
    collision = float(rng.random() < 0.4)
    if collision:
        ox, oy = rng.uniform(-0.25, 0.25), rng.uniform(-0.1, 0.3)
        obst = ((np.abs(x - ox) < 0.12) & (np.abs(y - oy) < 0.12)) * 100
        img = np.maximum(img, obst)
    img += rng.normal(0, 4, (size, size))
    img = np.clip(img - img.mean(), -128, 127)
    return img.astype(np.float32)[None], np.float32(steer), np.float32(collision)


def corridor_dataset(n: int, seed: int = 0, size: int = 96):
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, 1, size, size), np.float32)
    steer = np.zeros((n,), np.float32)
    coll = np.zeros((n,), np.float32)
    for i in range(n):
        xs[i], steer[i], coll[i] = corridor_image(rng, size)
    return xs, steer, coll
