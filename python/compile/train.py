"""Optional build-time trainers for the accuracy experiments (E7).

Two small training loops over the synthetic datasets (data.py — the
CIFAR10 / DVS-Gesture substitutions documented in DESIGN.md §1):

  * ternary CNN (CUTIE) — straight-through-estimator training of a reduced
    ternary classifier on the 10-class shape set.
  * gesture CSNN (SNE) — surrogate-gradient training of a reduced spiking
    classifier on the 11-class event-gesture set.

Both train latent float weights and quantize on the forward pass (STE), the
standard recipe for the networks the paper deploys. Invoked by
``make trained`` (NOT part of the default artifact build — the perf path is
weight-independent); writes artifacts/accuracy.json consumed by the
soa_comparison bench narrative and EXPERIMENTS.md §E7.

Networks here are reduced (fewer channels, smaller inputs) so the whole
run stays in CPU-minutes; the *claim* being reproduced is the shape —
"a ternary/spiking network trains to high accuracy on this task class" —
not an absolute SoA number (that needs the real datasets).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .kernels import ref

# ---------------------------------------------------------------------------
# Straight-through quantizers
# ---------------------------------------------------------------------------

def ste_ternarize(w, thr):
    """Forward: ternarize; backward: identity (straight-through)."""
    q = ref.ternarize(w, thr)
    return w + jax.lax.stop_gradient(q - w)


def _ste_spike(v, v_th, beta):
    """Forward: hard threshold. Backward: sigmoid surrogate slope
    beta * sig * (1 - sig) — steep near threshold, flat far away."""
    s = (v >= v_th).astype(v.dtype)
    smooth = jax.nn.sigmoid(beta * (v - v_th))
    return jax.lax.stop_gradient(s - smooth) + smooth


# ---------------------------------------------------------------------------
# Ternary classifier (CUTIE substitution)
# ---------------------------------------------------------------------------

def init_tnet(key, width=32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (width, 3, 3, 3)) * 0.3,
        "w2": jax.random.normal(k2, (width, width, 3, 3)) * 0.2,
        "fc": jax.random.normal(k3, (width, 10)) * 0.2,
    }


def tnet_forward(params, x, thr=0.05):
    w1 = ste_ternarize(params["w1"], thr)
    w2 = ste_ternarize(params["w2"], thr)
    h = jax.nn.relu(ref.conv2d(x, w1))
    h = ref.maxpool2(h)
    h = jax.nn.relu(ref.conv2d(h, w2))
    feat = ref.avgpool_global(h)
    return feat @ params["fc"]


def train_ternary(steps=300, batch=32, lr=0.02, seed=0):
    xs, ys = data.shape_dataset(1024, seed=seed)
    xs = data.ternarize_images(xs)
    xt, yt = data.shape_dataset(256, seed=seed + 1)
    xt = data.ternarize_images(xt)
    params = init_tnet(jax.random.PRNGKey(seed))

    def loss_fn(p, xb, yb):
        logits = jax.vmap(lambda x: tnet_forward(p, x))(xb)
        onehot = jax.nn.one_hot(yb, 10)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, axis=-1))

    @jax.jit
    def step(p, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
        return p, l

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, len(xs), batch)
        params, l = step(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        if i % 100 == 0:
            print(f"[ternary] step {i}: loss {float(l):.3f}")

    @jax.jit
    def predict(p, xb):
        return jnp.argmax(jax.vmap(lambda x: tnet_forward(p, x))(xb), axis=-1)

    acc = float(jnp.mean(predict(params, jnp.asarray(xt)) == jnp.asarray(yt)))
    print(f"[ternary] test accuracy: {acc * 100:.1f}% (chance 10%)")
    return acc


# ---------------------------------------------------------------------------
# Spiking gesture classifier (SNE substitution)
# ---------------------------------------------------------------------------

def init_snn(key, ch=16, classes=11):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (ch, 2, 3, 3)) * 0.4,
        "w2": jax.random.normal(k2, (ch, ch, 3, 3)) * 0.3,
        "fc": jax.random.normal(k3, (ch, classes)) * 0.3,
    }


def snn_forward(params, ev_seq, decay=0.875, v_th=1.0):
    """ev_seq: (T, 2, S, S) -> accumulated class logits."""
    ch = params["w1"].shape[0]
    s = ev_seq.shape[-1]
    v1 = jnp.zeros((ch, s, s))
    v2 = jnp.zeros((ch, s // 2, s // 2))
    acc = jnp.zeros(params["fc"].shape[1])
    for t in range(ev_seq.shape[0]):
        c1 = ref.conv2d(ev_seq[t], params["w1"])
        v1 = decay * v1 + c1
        s1 = _ste_spike(v1, v_th, 4.0)
        v1 = v1 - jax.lax.stop_gradient(s1) * v_th
        p1 = ref.maxpool2(s1)
        c2 = ref.conv2d(p1, params["w2"])
        v2 = decay * v2 + c2
        s2 = _ste_spike(v2, v_th, 4.0)
        v2 = v2 - jax.lax.stop_gradient(s2) * v_th
        acc = acc + ref.avgpool_global(s2) @ params["fc"]
    return acc


def train_gesture(steps=200, batch=16, lr=0.05, seed=0, t_steps=8, size=16):
    xs, ys = data.gesture_dataset(384, t_steps=t_steps, seed=seed, size=size)
    xt, yt = data.gesture_dataset(128, t_steps=t_steps, seed=seed + 1, size=size)
    params = init_snn(jax.random.PRNGKey(seed))

    def loss_fn(p, xb, yb):
        logits = jax.vmap(lambda e: snn_forward(p, e))(xb)
        onehot = jax.nn.one_hot(yb, 11)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits) * onehot, axis=-1))

    @jax.jit
    def step(p, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
        return p, l

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, len(xs), batch)
        params, l = step(params, jnp.asarray(xs[idx]), jnp.asarray(ys[idx]))
        if i % 50 == 0:
            print(f"[gesture] step {i}: loss {float(l):.3f}")

    @jax.jit
    def predict(p, xb):
        return jnp.argmax(jax.vmap(lambda e: snn_forward(p, e))(xb), axis=-1)

    acc = float(jnp.mean(predict(params, jnp.asarray(xt)) == jnp.asarray(yt)))
    print(f"[gesture] test accuracy: {acc * 100:.1f}% (chance 9.1%)")
    return acc


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    t0 = time.time()
    acc_t = train_ternary(steps=args.steps)
    acc_g = train_gesture(steps=max(100, args.steps // 2))
    os.makedirs(args.outdir, exist_ok=True)
    out = {
        "ternary_shapes_accuracy": acc_t,
        "gesture_accuracy": acc_g,
        "paper_context": {
            "cutie_cifar10_vs_binareye": "+2% (real dataset; not reproduced)",
            "sne_dvs_gesture": 0.92,
        },
        "train_seconds": time.time() - t0,
    }
    path = os.path.join(args.outdir, "accuracy.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"[train] wrote {path} in {out['train_seconds']:.0f}s")


if __name__ == "__main__":
    main()
