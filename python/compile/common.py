"""Shared configuration for the Kraken compile path (L1/L2).

These dataclasses pin the *functional* network shapes that get AOT-compiled
into artifacts/. The Rust side carries its own workload descriptors for the
paper-sized networks (rust/src/nets/) used by the timing/energy models; the
manifest emitted by aot.py lets Rust cross-check that both views agree on
shapes, MAC counts and parameter footprints.

Artifact sizes are deliberately compact (64x64 DVS, 32x32 CIFAR-like,
96x96 DroNet input) so `make artifacts` stays fast on CPU; all sizes are
configurable here and flow through model.py, aot.py and the tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field

SEED = 0xC0FFEE


@dataclass(frozen=True)
class FireNetCfg:
    """LIF-FireNet (Hagenaars et al. [4]) — 4-layer CSNN optical flow."""

    height: int = 64
    width: int = 64
    in_ch: int = 2            # DVS polarities
    hidden: tuple = (16, 32, 32, 16)
    flow_ch: int = 2          # (u, v) per-pixel flow
    ksize: int = 3
    decay: float = 0.875      # leak multiplier (7/8: shift-friendly, as SNE)
    v_th: float = 1.0
    w_bits: int = 4           # SNE supports 4-bit kernels

    @property
    def state_shapes(self):
        h, w = self.height, self.width
        return [(c, h, w) for c in self.hidden]


@dataclass(frozen=True)
class CutieCfg:
    """Ternary CNN in CUTIE's mold: 96-wide, 3x3, all weights on-chip."""

    in_size: int = 32
    in_ch: int = 3
    width: int = 96           # CUTIE computes 96 output channels in parallel
    n_layers: int = 7
    pool_after: tuple = (2, 4)  # 1-indexed layers followed by 2x2 maxpool
    n_classes: int = 10
    ksize: int = 3


@dataclass(frozen=True)
class DroNetCfg:
    """8-bit quantized DroNet (Palossi et al. [2]) — steering + collision."""

    in_size: int = 96
    in_ch: int = 1
    stem_ch: int = 16
    block_ch: tuple = (32, 64, 96)
    acc_shift: float = 7.0    # requantization shift after each conv

    @property
    def n_outputs(self):
        return 2              # steering angle, collision probability


@dataclass(frozen=True)
class GestureCfg:
    """6-layer CSNN for the DVS-Gesture-like accuracy benchmark."""

    in_size: int = 32
    in_ch: int = 2
    channels: tuple = (16, 16, 32, 32, 64)
    pool_after: tuple = (2, 4)  # 1-indexed conv layers followed by pool
    n_classes: int = 11         # as IBM DVS-Gesture
    decay: float = 0.875
    v_th: float = 1.0
    timesteps: int = 16


@dataclass(frozen=True)
class BuildCfg:
    firenet: FireNetCfg = field(default_factory=FireNetCfg)
    cutie: CutieCfg = field(default_factory=CutieCfg)
    dronet: DroNetCfg = field(default_factory=DroNetCfg)
    gesture: GestureCfg = field(default_factory=GestureCfg)


DEFAULT = BuildCfg()
