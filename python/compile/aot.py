"""AOT compile path: lower the L2 models to HLO *text* artifacts + manifest.

This is the only place Python touches the deployed system. `make artifacts`
runs it once; the Rust coordinator then loads ``artifacts/*.hlo.txt`` through
PJRT (rust/src/runtime/) and never calls back into Python.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each artifact is a pure function with params baked in as constants
(deterministic seeds — see compile.common.SEED), flat f32 inputs/outputs,
lowered with return_tuple=True. artifacts/manifest.json describes every
input/output tensor plus workload statistics that the Rust side cross-checks
against its own net descriptors (rust/src/nets/).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .common import DEFAULT, SEED


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer elides
    # big weight tensors as `constant({...})`, which the xla_extension 0.5.1
    # text parser silently reads back as ZEROS — the network would "run"
    # with all-zero weights on the Rust side. test_aot.py pins this.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _io(names_shapes):
    return [
        {"name": n, "shape": list(s), "dtype": "f32"} for n, s in names_shapes
    ]


def build_firenet(cfg):
    params = model.init_firenet(cfg.firenet)
    fc = cfg.firenet

    def step(x, v0, v1, v2, v3):
        flow, states, counts = model.firenet_step(
            params, fc, x, [v0, v1, v2, v3]
        )
        return (flow, *states, counts)

    in_specs = [_spec((fc.in_ch, fc.height, fc.width))] + [
        _spec(s) for s in fc.state_shapes
    ]
    lowered = jax.jit(step).lower(*in_specs)
    inputs = _io(
        [("events", (fc.in_ch, fc.height, fc.width))]
        + [(f"v{i}", s) for i, s in enumerate(fc.state_shapes)]
    )
    outputs = _io(
        [("flow", (fc.flow_ch, fc.height, fc.width))]
        + [(f"v{i}", s) for i, s in enumerate(fc.state_shapes)]
        + [("spike_counts", (len(fc.hidden),))]
    )
    return lowered, inputs, outputs, model.firenet_stats(fc)


def build_firenet_window(cfg):
    """Whole 5-step inference window in one artifact (lax.scan over steps):
    state stays device-side across timesteps, cutting PJRT marshalling 5x —
    the coordinator's preferred hot-path artifact (EXPERIMENTS.md §Perf)."""
    params = model.init_firenet(cfg.firenet)
    fc = cfg.firenet
    t_steps = 5

    def window(xs, v0, v1, v2, v3):
        # UNROLLED over timesteps (not lax.scan): the xla_extension 0.5.1
        # runtime executes HLO while-loops without loop-body fusion, at
        # ~40x the cost of the equivalent straight-line code.
        states = [v0, v1, v2, v3]
        total = jnp.zeros((len(fc.hidden),))
        flow = None
        for t in range(t_steps):
            flow, states, counts = model.firenet_step(params, fc, xs[t], states)
            total = total + counts
        return (flow, *states, total)

    in_specs = [_spec((t_steps, fc.in_ch, fc.height, fc.width))] + [
        _spec(s) for s in fc.state_shapes
    ]
    lowered = jax.jit(window).lower(*in_specs)
    inputs = _io(
        [("event_bins", (t_steps, fc.in_ch, fc.height, fc.width))]
        + [(f"v{i}", s) for i, s in enumerate(fc.state_shapes)]
    )
    outputs = _io(
        [("flow", (fc.flow_ch, fc.height, fc.width))]
        + [(f"v{i}", s) for i, s in enumerate(fc.state_shapes)]
        + [("spike_counts", (len(fc.hidden),))]
    )
    return lowered, inputs, outputs, model.firenet_stats(fc)


def build_cutie(cfg):
    params = model.init_cutie(cfg.cutie)
    cc = cfg.cutie

    def fwd(x):
        logits, nz = model.cutie_forward(params, cc, x)
        return (logits, nz)

    lowered = jax.jit(fwd).lower(_spec((cc.in_ch, cc.in_size, cc.in_size)))
    inputs = _io([("image_t", (cc.in_ch, cc.in_size, cc.in_size))])
    outputs = _io(
        [("logits", (cc.n_classes,)), ("nz_frac", (cc.n_layers,))]
    )
    return lowered, inputs, outputs, model.cutie_stats(cc)


def build_dronet(cfg):
    params = model.init_dronet(cfg.dronet)
    dc = cfg.dronet

    def fwd(x):
        return (model.dronet_forward(params, dc, x),)

    lowered = jax.jit(fwd).lower(_spec((dc.in_ch, dc.in_size, dc.in_size)))
    inputs = _io([("image", (dc.in_ch, dc.in_size, dc.in_size))])
    outputs = _io([("steer_coll", (2,))])
    return lowered, inputs, outputs, model.dronet_stats(dc)


def build_gesture(cfg):
    params = model.init_gesture(cfg.gesture)
    gc = cfg.gesture
    shapes = model.gesture_state_shapes(gc)

    def step(x, v0, v1, v2, v3, v4, acc):
        states, acc2, counts = model.gesture_step(
            params, gc, x, [v0, v1, v2, v3, v4], acc
        )
        return (*states, acc2, counts)

    in_specs = (
        [_spec((gc.in_ch, gc.in_size, gc.in_size))]
        + [_spec(s) for s in shapes]
        + [_spec((gc.n_classes,))]
    )
    lowered = jax.jit(step).lower(*in_specs)
    inputs = _io(
        [("events", (gc.in_ch, gc.in_size, gc.in_size))]
        + [(f"v{i}", s) for i, s in enumerate(shapes)]
        + [("acc", (gc.n_classes,))]
    )
    outputs = _io(
        [(f"v{i}", s) for i, s in enumerate(shapes)]
        + [("acc", (gc.n_classes,)), ("spike_counts", (len(gc.channels),))]
    )
    return lowered, inputs, outputs, {}


BUILDERS = {
    "firenet": (build_firenet, "sne"),
    "firenet_window": (build_firenet_window, "sne"),
    "cutie": (build_cutie, "cutie"),
    "dronet": (build_dronet, "pulp"),
    "gesture": (build_gesture, "sne"),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifacts to build")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"seed": SEED, "artifacts": {}}
    names = args.only or list(BUILDERS)
    for name in names:
        builder, engine = BUILDERS[name]
        lowered, inputs, outputs, stats = builder(DEFAULT)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "engine": engine,
            "inputs": inputs,
            "outputs": outputs,
            "stats": stats,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"[aot] {name}: {len(text)} chars -> {path}")

    mpath = os.path.join(args.outdir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] manifest -> {mpath}")


if __name__ == "__main__":
    main()
