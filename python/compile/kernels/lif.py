"""Pallas kernel: fused LIF neuron-state update (SNE hot spot, L1).

The SNE datapath turns sparse events into dense bursts over its eight LIF
neuron-state memories. The TPU analogue (see DESIGN.md §Hardware-Adaptation)
is a fused elementwise pass over the whole state tensor, tiled so each block
fits VMEM and streams HBM<->VMEM once per timestep:

    v' = decay * v + x ; spike = v' >= v_th ; v'' = v' - spike * v_th

All three reads/writes (state in, current in, state out + spikes out) are
fused into one kernel so the state never round-trips through HBM between the
integrate / fire / reset phases — the same reason SNE keeps neuron state in
its eight 8 KiB SRAM banks.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust CPU client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size for the flattened neuron-state vector. 128 Ki f32 = 512 KiB per
# ref; with 4 refs live (v, x, v_out, s_out) a block occupies 2 MiB of VMEM —
# comfortably under the ~16 MiB budget while making every FireNet layer a
# single grid step. (Perf note, EXPERIMENTS.md §Perf: at the original 8 Ki
# block the interpret-mode grid loop doubled artifact latency: 8.7 ms vs
# 4.3 ms per FireNet step on the build machine.)
_BLOCK = 128 * 1024


def _lif_kernel(v_ref, x_ref, decay_ref, vth_ref, v_out_ref, s_out_ref):
    decay = decay_ref[0]
    v_th = vth_ref[0]
    v_int = decay * v_ref[...] + x_ref[...]
    spikes = (v_int >= v_th).astype(v_int.dtype)
    v_out_ref[...] = v_int - spikes * v_th
    s_out_ref[...] = spikes


@functools.partial(jax.jit, static_argnames=("interpret",))
def lif_update(v, x, decay, v_th, *, interpret=True):
    """Fused LIF update over an arbitrary-shaped state tensor.

    Args:
      v: membrane state (any shape, f32).
      x: input current, same shape.
      decay, v_th: scalars (f32).

    Returns:
      (v_next, spikes), same shape as ``v``.
    """
    shape = v.shape
    n = v.size
    # Pad the flattened state to a whole number of blocks.
    n_pad = (-n) % _BLOCK
    vf = jnp.pad(v.reshape(-1), (0, n_pad))
    xf = jnp.pad(x.reshape(-1), (0, n_pad))
    grid = (vf.size // _BLOCK,)

    v_out, s_out = pl.pallas_call(
        _lif_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(vf.shape, vf.dtype),
            jax.ShapeDtypeStruct(vf.shape, vf.dtype),
        ],
        interpret=interpret,
    )(vf, xf, jnp.asarray([decay], vf.dtype), jnp.asarray([v_th], vf.dtype))

    return v_out[:n].reshape(shape), s_out[:n].reshape(shape)
