"""Pallas kernel: int8-style widening GEMM (PULP SIMD hot spot, L1).

PULP's energy win at low precision comes from SIMD widening dot-products
(int8/int4/int2 -> 32-bit accumulate) with MAC-LD keeping the MACs fed at
0.98 mac/cycle/core. The TPU analogue is a blocked GEMM with a widening
accumulate and a fused requantization epilogue (arithmetic shift + clip),
so quantized activations go HBM->VMEM->MXU->VMEM->HBM exactly once.

Values are small integers carried in f32 (exact up to 2^24); the kernel is
bit-accurate w.r.t. an integer implementation for our operand ranges, which
the hypothesis sweep in python/tests/test_kernels.py asserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_M_BLK = 128
_N_BLK = 128


def _int8_gemm_kernel(p_ref, w_ref, shift_ref, o_ref):
    acc = jnp.dot(p_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = jnp.floor(acc / (2.0 ** shift_ref[0]))
    o_ref[...] = jnp.clip(y, -128.0, 127.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_gemm(patches, w_mat, acc_shift, *, interpret=True):
    """Widening GEMM + requantize (shift) + clip to int8 range.

    Args:
      patches: (M, K) f32 with integer values in [-128, 127].
      w_mat: (K, N) f32 with integer values in [-128, 127].
      acc_shift: scalar f32 power-of-two right shift.

    Returns:
      (M, N) f32 with integer values in [-128, 127].
    """
    m, k = patches.shape
    k2, n = w_mat.shape
    assert k == k2, f"K mismatch {k} vs {k2}"

    m_pad = (-m) % _M_BLK
    n_pad = (-n) % _N_BLK
    p = jnp.pad(patches, ((0, m_pad), (0, 0)))
    w = jnp.pad(w_mat, ((0, 0), (0, n_pad)))

    grid = (p.shape[0] // _M_BLK, w.shape[1] // _N_BLK)
    out = pl.pallas_call(
        _int8_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_M_BLK, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _N_BLK), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((_M_BLK, _N_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p.shape[0], w.shape[1]), patches.dtype),
        interpret=interpret,
    )(p, w, jnp.asarray([acc_shift], patches.dtype))
    return out[:m, :n]
