"""Pure-jnp reference oracles for the Pallas kernels (L1 ground truth).

Every Pallas kernel in this package has an oracle here; pytest checks them
against each other with hypothesis-driven shape/value sweeps. The oracles are
also what the L2 model would compute if the Pallas kernels were replaced by
plain jnp — they define functional correctness for the whole compile path.

Conventions
-----------
* Feature maps are CHW (channels, height, width), matching the SNE/CUTIE
  on-chip layouts in the paper (channel-major neuron state memories).
* Quantized values (int8 / int4 / ternary) travel as f32 holding exact small
  integers; this keeps PJRT marshalling on the Rust side to a single dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LIF dynamics (SNE)
# ---------------------------------------------------------------------------

def lif_step(v, x, decay, v_th):
    """One leaky-integrate-and-fire step with reset-by-subtraction.

    v' = decay * v + x ; spike = (v' >= v_th) ; v'' = v' - spike * v_th

    Matches the SNE datapath: 8-bit neuron state, 4-bit weights feeding the
    input current ``x``; here state is f32 but the update law is identical.

    Args:
      v: membrane state, any shape.
      x: input current, same shape as ``v``.
      decay: scalar leak multiplier in [0, 1].
      v_th: scalar firing threshold (> 0).

    Returns:
      (v_next, spikes) with ``spikes`` in {0.0, 1.0}.
    """
    v_int = decay * v + x
    spikes = (v_int >= v_th).astype(v.dtype)
    v_next = v_int - spikes * v_th
    return v_next, spikes


def lif_step_hard_reset(v, x, decay, v_th):
    """LIF step with reset-to-zero (used by the gesture classifier head)."""
    v_int = decay * v + x
    spikes = (v_int >= v_th).astype(v.dtype)
    v_next = jnp.where(spikes > 0, jnp.zeros_like(v_int), v_int)
    return v_next, spikes


# ---------------------------------------------------------------------------
# Convolutions
# ---------------------------------------------------------------------------

def conv2d(x, w, stride=1, padding="SAME"):
    """Plain f32 conv. x: (C_in, H, W), w: (C_out, C_in, kh, kw)."""
    out = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def im2col(x, kh, kw, stride=1):
    """Unfold (C,H,W) -> (H_out*W_out, C*kh*kw) patch matrix, SAME padding.

    This is the dataflow transform CUTIE performs spatially in silicon (all
    kh*kw*C_in products of one output pixel issued at once); on TPU we
    materialise it so the MXU sees a dense GEMM.
    """
    c, h, w = x.shape
    # XLA "SAME" convention: out = ceil(in/stride), total padding split with
    # the extra unit on the high side (matters for stride > 1, even sizes).
    h_out = -(-h // stride)
    w_out = -(-w // stride)
    pht = max((h_out - 1) * stride + kh - h, 0)
    pwt = max((w_out - 1) * stride + kw - w, 0)
    ph, pw = pht // 2, pwt // 2
    xp = jnp.pad(x, ((0, 0), (ph, pht - ph), (pw, pwt - pw)))
    idx_h = jnp.arange(h_out) * stride
    idx_w = jnp.arange(w_out) * stride
    patches = jnp.stack(
        [
            xp[:, idx_h[:, None] + dh, idx_w[None, :] + dw]
            for dh in range(kh)
            for dw in range(kw)
        ],
        axis=-1,
    )  # (c, h_out, w_out, kh*kw)
    patches = jnp.transpose(patches, (1, 2, 0, 3))  # (h_out, w_out, c, kh*kw)
    return patches.reshape(h_out * w_out, c * kh * kw)


def ternary_conv(x, w, thr_lo, thr_hi, stride=1):
    """Ternary convolution with fused ternarization (CUTIE OCU semantics).

    x: (C_in, H, W) with values in {-1, 0, +1} (f32).
    w: (C_out, C_in, kh, kw) with values in {-1, 0, +1} (f32).
    thr_lo, thr_hi: per-channel (C_out,) thresholds. Output is
      +1 where acc > thr_hi, -1 where acc < thr_lo, else 0,
    which is CUTIE's "multi-bit accumulate -> per-channel normalization +
    thresholding" output stage folded into one comparison pair.

    Returns (C_out, H_out, W_out) ternary f32 and the raw accumulator.
    """
    acc = conv2d(x, w, stride=stride)
    t = jnp.where(
        acc > thr_hi[:, None, None],
        1.0,
        jnp.where(acc < thr_lo[:, None, None], -1.0, 0.0),
    ).astype(x.dtype)
    return t, acc


def conv2d_int8(x_q, w_q, acc_shift, stride=1):
    """Int8-style conv with widening accumulate and requantize-by-shift.

    x_q: (C_in, H, W) integers in [-128, 127] stored as f32.
    w_q: (C_out, C_in, kh, kw) integers in [-128, 127] stored as f32.
    acc_shift: scalar power-of-two right shift for requantization.

    The widened accumulator stays exactly representable in f32 for our sizes
    (|acc| < 2^23); the requantized output is clipped back to int8 range,
    mirroring PULP's SIMD dotp + normalization kernels.
    """
    acc = conv2d(x_q, w_q, stride=stride)
    y = jnp.floor(acc / (2.0 ** acc_shift))
    return jnp.clip(y, -128.0, 127.0)


# ---------------------------------------------------------------------------
# GEMM-shaped oracles (what the Pallas kernels actually implement)
# ---------------------------------------------------------------------------

def ternary_gemm(patches, w_mat, thr_lo, thr_hi):
    """patches: (M, K); w_mat: (K, N) ternary; thresholds (N,).

    Returns ternarized (M, N). Oracle for kernels.ternary_conv.ternary_gemm.
    """
    acc = patches @ w_mat
    return jnp.where(acc > thr_hi[None, :], 1.0,
                     jnp.where(acc < thr_lo[None, :], -1.0, 0.0)
                     ).astype(patches.dtype)


def int8_gemm(patches, w_mat, acc_shift):
    """Oracle for kernels.conv_int8.int8_gemm: widening GEMM + shift + clip."""
    acc = patches @ w_mat
    y = jnp.floor(acc / (2.0 ** acc_shift))
    return jnp.clip(y, -128.0, 127.0)


# ---------------------------------------------------------------------------
# Pooling / misc building blocks used by the L2 models
# ---------------------------------------------------------------------------

def maxpool2(x):
    """2x2/2 max pool, x: (C, H, W) with even H, W."""
    c, h, w = x.shape
    return jnp.max(x.reshape(c, h // 2, 2, w // 2, 2), axis=(2, 4))


def avgpool_global(x):
    """Global average pool, x: (C, H, W) -> (C,)."""
    return jnp.mean(x, axis=(1, 2))


def quantize_sym(x, n_bits):
    """Symmetric uniform quantizer to n_bits, returns integer-valued f32."""
    qmax = 2.0 ** (n_bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    return jnp.round(x / scale), scale


def ternarize(x, thr):
    """Elementwise ternarization with symmetric threshold."""
    return jnp.where(x > thr, 1.0, jnp.where(x < -thr, -1.0, 0.0))
