"""L1 Pallas kernels for the Kraken reproduction.

- ``lif``: fused LIF neuron update (SNE).
- ``ternary_conv``: ternary GEMM with fused thresholding (CUTIE).
- ``conv_int8``: widening int8 GEMM with fused requantization (PULP).
- ``ref``: pure-jnp oracles for all of the above.
"""

from . import conv_int8, lif, ref, ternary_conv  # noqa: F401
