"""Pallas kernel: ternary GEMM with fused thresholding (CUTIE hot spot, L1).

CUTIE computes one output pixel per cycle across 96 output channels by
spatially unrolling every ternary multiply of a 3x3xC_in receptive field and
compressing weights to 1.6 b/trit so the full network stays on-chip. On TPU
(DESIGN.md §Hardware-Adaptation) the analogue is a dense GEMM on the MXU:

    patches (M, K)  @  w (K, N in {-1,0,+1})  ->  acc (M, N)
    out = +1 / 0 / -1 by per-channel double threshold   (fused epilogue)

The im2col unfold happens in the surrounding jnp (it is pure data movement —
XLA fuses it into the feed); the Pallas kernel owns the multiply-accumulate
and CUTIE's output stage (per-channel normalization + thresholding), so the
wide accumulator never leaves VMEM — exactly CUTIE's "minimize data
movement" argument transposed to the memory hierarchy we have.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles. K is kept whole per block (K = 9*C_in <= 9*96 = 864,
# i.e. <= 3.4 KiB/row) so the accumulator for one (M_BLK, N_BLK) tile lives
# entirely in VMEM. M_BLK = 1024 covers a whole 32x32 layer in one grid step
# (LHS tile 1024x864 f32 = 3.4 MiB VMEM — fits; -12% artifact latency vs
# 128-row tiles under interpret mode, see EXPERIMENTS.md §Perf).
_M_BLK = 128
_N_BLK = 128


def _ternary_gemm_kernel(p_ref, w_ref, lo_ref, hi_ref, o_ref):
    acc = jnp.dot(p_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    lo = lo_ref[...]
    hi = hi_ref[...]
    o_ref[...] = jnp.where(
        acc > hi[None, :], 1.0, jnp.where(acc < lo[None, :], -1.0, 0.0)
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ternary_gemm(patches, w_mat, thr_lo, thr_hi, *, interpret=True):
    """Ternary GEMM + fused per-channel double-threshold ternarization.

    Args:
      patches: (M, K) f32 im2col patch matrix, entries in {-1, 0, +1}.
      w_mat: (K, N) f32 ternary weights.
      thr_lo, thr_hi: (N,) per-output-channel thresholds.

    Returns:
      (M, N) f32 in {-1, 0, +1}.
    """
    m, k = patches.shape
    k2, n = w_mat.shape
    assert k == k2, f"K mismatch {k} vs {k2}"

    m_pad = (-m) % _M_BLK
    n_pad = (-n) % _N_BLK
    p = jnp.pad(patches, ((0, m_pad), (0, 0)))
    w = jnp.pad(w_mat, ((0, 0), (0, n_pad)))
    lo = jnp.pad(thr_lo, (0, n_pad))
    hi = jnp.pad(thr_hi, (0, n_pad))

    grid = (p.shape[0] // _M_BLK, w.shape[1] // _N_BLK)
    out = pl.pallas_call(
        _ternary_gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((_M_BLK, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _N_BLK), lambda i, j: (0, j)),
            pl.BlockSpec((_N_BLK,), lambda i, j: (j,)),
            pl.BlockSpec((_N_BLK,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((_M_BLK, _N_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p.shape[0], w.shape[1]), patches.dtype),
        interpret=interpret,
    )(p, w, lo, hi)
    return out[:m, :n]
