"""Pytest path setup: make `compile` (python/compile) importable when the
suite is run from the repo root (`python -m pytest python/tests`)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))
