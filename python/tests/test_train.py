"""Build-time trainer: a few steps must beat chance (E7 substitution)."""

import pytest

from compile import train


@pytest.mark.slow
def test_ternary_trains_above_chance():
    acc = train.train_ternary(steps=80, batch=32, seed=3)
    assert acc > 0.2, f"ternary accuracy {acc} should beat 10% chance clearly"


def test_ste_ternarize_preserves_gradient_path():
    import jax
    import jax.numpy as jnp

    def loss(w):
        return jnp.sum(train.ste_ternarize(w, 0.05) * 2.0)

    g = jax.grad(loss)(jnp.asarray([0.3, -0.2, 0.01]))
    # straight-through: gradient flows as if identity
    assert all(abs(float(x) - 2.0) < 1e-6 for x in g)


def test_ste_spike_surrogate_gradient_nonzero_near_threshold():
    import jax
    import jax.numpy as jnp

    def loss(v):
        return jnp.sum(train._ste_spike(v, 1.0, 4.0))

    g = jax.grad(loss)(jnp.asarray([0.95, 1.05, 5.0]))
    assert float(g[0]) > 0.1 and float(g[1]) > 0.1, "steep near threshold"
    assert float(g[2]) < 0.01, "flat far from threshold"
