"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; assert_allclose with
tight tolerances (the GEMM kernels are exact on integer-valued f32, the LIF
kernel is within FMA reassociation noise).
"""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_int8, lif, ref, ternary_conv

SET = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# LIF
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    c=st.integers(1, 8),
    h=st.integers(1, 33),
    w=st.integers(1, 33),
    decay=st.floats(0.0, 1.0),
    v_th=st.floats(0.25, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lif_matches_ref(c, h, w, decay, v_th, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(c, h, w)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(c, h, w)).astype(np.float32))
    v2, s = lif.lif_update(v, x, decay, v_th)
    vr, sr = ref.lif_step(v, x, decay, v_th)
    npt.assert_allclose(np.asarray(v2), np.asarray(vr), rtol=1e-5, atol=1e-5)
    npt.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_lif_spikes_are_binary():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32) * 3)
    x = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32) * 3)
    _, s = lif.lif_update(v, x, 0.9, 1.0)
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}


def test_lif_reset_by_subtraction():
    # A neuron exactly at threshold fires and is left at 0.
    v = jnp.zeros((1, 1, 1))
    x = jnp.ones((1, 1, 1))
    v2, s = lif.lif_update(v, x, 1.0, 1.0)
    assert float(s[0, 0, 0]) == 1.0
    assert float(v2[0, 0, 0]) == 0.0


def test_lif_no_input_decays():
    v = jnp.full((1, 4, 4), 0.5)
    v2, s = lif.lif_update(v, jnp.zeros_like(v), 0.5, 1.0)
    npt.assert_allclose(np.asarray(v2), 0.25)
    assert float(jnp.sum(s)) == 0.0


def test_lif_threshold_monotonicity():
    """Higher threshold can never produce more spikes."""
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.normal(size=(8, 32, 32)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(8, 32, 32)).astype(np.float32))
    counts = [
        float(jnp.sum(lif.lif_update(v, x, 0.875, th)[1]))
        for th in (0.5, 1.0, 2.0, 4.0)
    ]
    assert counts == sorted(counts, reverse=True)


def test_lif_large_padded_shape():
    """Shapes that are not multiples of the block size pad correctly."""
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.normal(size=(16, 65, 67)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(16, 65, 67)).astype(np.float32))
    v2, s = lif.lif_update(v, x, 0.875, 1.0)
    vr, sr = ref.lif_step(v, x, 0.875, 1.0)
    npt.assert_allclose(np.asarray(v2), np.asarray(vr), rtol=1e-5, atol=1e-5)
    npt.assert_array_equal(np.asarray(s), np.asarray(sr))


# ---------------------------------------------------------------------------
# Ternary GEMM (CUTIE)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 128),
    n=st.integers(1, 130),
    seed=st.integers(0, 2**31 - 1),
)
def test_ternary_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.integers(-1, 2, size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.integers(-1, 2, size=(k, n)).astype(np.float32))
    thr = jnp.asarray(np.abs(rng.normal(size=n)).astype(np.float32) * 3)
    out = ternary_conv.ternary_gemm(p, w, -thr, thr)
    outr = ref.ternary_gemm(p, w, -thr, thr)
    npt.assert_array_equal(np.asarray(out), np.asarray(outr))


def test_ternary_gemm_output_is_ternary():
    rng = np.random.default_rng(11)
    p = jnp.asarray(rng.integers(-1, 2, size=(64, 27)).astype(np.float32))
    w = jnp.asarray(rng.integers(-1, 2, size=(27, 96)).astype(np.float32))
    thr = jnp.full((96,), 2.0)
    out = ternary_conv.ternary_gemm(p, w, -thr, thr)
    assert set(np.unique(np.asarray(out))) <= {-1.0, 0.0, 1.0}


def test_ternary_conv_via_im2col_matches_direct_conv():
    """The im2col + GEMM path equals a direct lax conv."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-1, 2, size=(3, 16, 16)).astype(np.float32))
    w = jnp.asarray(rng.integers(-1, 2, size=(8, 3, 3, 3)).astype(np.float32))
    thr = jnp.asarray(np.abs(rng.normal(size=8)).astype(np.float32) * 4)
    patches = ref.im2col(x, 3, 3)
    w_mat = w.reshape(8, -1).T
    y = ternary_conv.ternary_gemm(patches, w_mat, -thr, thr)
    y = y.T.reshape(8, 16, 16)
    yd, _ = ref.ternary_conv(x, w, -thr, thr)
    npt.assert_array_equal(np.asarray(y), np.asarray(yd))


def test_ternary_zero_weights_zero_output():
    p = jnp.ones((8, 9))
    w = jnp.zeros((9, 4))
    thr = jnp.full((4,), 0.5)
    out = ternary_conv.ternary_gemm(p, w, -thr, thr)
    npt.assert_array_equal(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# Int8 GEMM (PULP)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m=st.integers(1, 150),
    k=st.integers(1, 96),
    n=st.integers(1, 100),
    shift=st.integers(0, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_gemm_matches_ref(m, k, n, shift, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.integers(-128, 128, size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.integers(-128, 128, size=(k, n)).astype(np.float32))
    out = conv_int8.int8_gemm(p, w, float(shift))
    outr = ref.int8_gemm(p, w, float(shift))
    npt.assert_array_equal(np.asarray(out), np.asarray(outr))


def test_int8_gemm_exact_integer_semantics():
    """The f32-carried GEMM is bit-exact vs int64 arithmetic."""
    rng = np.random.default_rng(9)
    p = rng.integers(-128, 128, size=(64, 96))
    w = rng.integers(-128, 128, size=(96, 32))
    acc = p @ w  # int64
    want = np.clip(np.floor(acc / 2.0**7), -128, 127)
    got = conv_int8.int8_gemm(
        jnp.asarray(p, jnp.float32), jnp.asarray(w, jnp.float32), 7.0
    )
    npt.assert_array_equal(np.asarray(got), want.astype(np.float32))


def test_int8_gemm_saturation():
    p = jnp.full((4, 64), 127.0)
    w = jnp.full((64, 4), 127.0)
    out = conv_int8.int8_gemm(p, w, 0.0)
    npt.assert_array_equal(np.asarray(out), 127.0)
    out = conv_int8.int8_gemm(p, -w, 0.0)
    npt.assert_array_equal(np.asarray(out), -128.0)


# ---------------------------------------------------------------------------
# im2col
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    c=st.integers(1, 6),
    h=st.sampled_from([8, 12, 16, 24]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_gemm_equals_conv(c, h, k, stride, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(c, h, h)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, c, k, k)).astype(np.float32))
    patches = ref.im2col(x, k, k, stride=stride)
    y = (patches @ w.reshape(4, -1).T).T
    h_out = (h + stride - 1) // stride
    y = y.reshape(4, h_out, h_out)
    yd = ref.conv2d(x, w, stride=stride)
    npt.assert_allclose(np.asarray(y), np.asarray(yd), rtol=1e-4, atol=1e-4)
