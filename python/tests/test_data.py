"""Synthetic dataset sanity: separability, determinism, value ranges."""

import numpy as np

from compile import data


def test_shape_dataset_shapes_and_labels():
    xs, ys = data.shape_dataset(32, seed=0)
    assert xs.shape == (32, 3, 32, 32)
    assert ys.min() >= 0 and ys.max() <= 9


def test_shape_dataset_deterministic():
    a, ya = data.shape_dataset(8, seed=5)
    b, yb = data.shape_dataset(8, seed=5)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ya, yb)


def test_ternarize_images_range():
    xs, _ = data.shape_dataset(4, seed=1)
    t = data.ternarize_images(xs)
    assert set(np.unique(t)) <= {-1.0, 0.0, 1.0}
    # ternarized images must not be all-zero (information preserved)
    assert np.abs(t).mean() > 0.02


def test_classes_are_visually_distinct():
    """Mean images of different classes differ substantially."""
    rng = np.random.default_rng(0)
    means = []
    for cls in range(10):
        imgs = np.stack([data.shape_image(cls, rng) for _ in range(8)])
        means.append(imgs.mean(axis=0))
    means = np.stack(means)
    for i in range(10):
        for j in range(i + 1, 10):
            assert np.abs(means[i] - means[j]).mean() > 0.01, (i, j)


def test_gesture_events_shape_and_polarity():
    ev = data.gesture_events(0, 16, seed=2)
    assert ev.shape == (16, 2, 32, 32)
    assert set(np.unique(ev)) <= {0.0, 1.0}


def test_gesture_events_active():
    """Every gesture class produces events (the DVS sees motion)."""
    for cls in range(11):
        ev = data.gesture_events(cls, 16, seed=3, noise=0.0)
        assert ev.sum() > 10, data.GESTURE_NAMES[cls]


def test_gesture_rotation_directions_differ():
    cw = data.gesture_events(0, 16, seed=4, noise=0.0)
    ccw = data.gesture_events(1, 16, seed=4, noise=0.0)
    assert np.abs(cw - ccw).sum() > 10


def test_gesture_activity_controllable_via_noise():
    lo = data.gesture_events(10, 16, seed=5, noise=0.0).mean()
    hi = data.gesture_events(10, 16, seed=5, noise=0.2).mean()
    assert hi > lo


def test_corridor_dataset():
    xs, steer, coll = data.corridor_dataset(16, seed=6)
    assert xs.shape == (16, 1, 96, 96)
    assert np.all(np.abs(steer) <= 0.8)
    assert set(np.unique(coll)) <= {0.0, 1.0}
    assert xs.min() >= -128 and xs.max() <= 127
