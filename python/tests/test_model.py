"""L2 correctness: network shapes, invariants, and Pallas-vs-jnp agreement."""

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt

from compile import data, model
from compile.common import DEFAULT


def _zeros(shapes):
    return [jnp.zeros(s) for s in shapes]


# ---------------------------------------------------------------------------
# FireNet
# ---------------------------------------------------------------------------

def test_firenet_step_shapes():
    cfg = DEFAULT.firenet
    params = model.init_firenet(cfg)
    x = jnp.zeros((cfg.in_ch, cfg.height, cfg.width))
    flow, states, counts = model.firenet_step(params, cfg, x, _zeros(cfg.state_shapes))
    assert flow.shape == (cfg.flow_ch, cfg.height, cfg.width)
    assert [s.shape for s in states] == [tuple(s) for s in cfg.state_shapes]
    assert counts.shape == (len(cfg.hidden),)


def test_firenet_zero_input_never_spikes():
    cfg = DEFAULT.firenet
    params = model.init_firenet(cfg)
    x = jnp.zeros((cfg.in_ch, cfg.height, cfg.width))
    _, _, counts = model.firenet_step(params, cfg, x, _zeros(cfg.state_shapes))
    assert float(jnp.sum(counts)) == 0.0


def test_firenet_activity_monotone_in_input():
    """Denser event input -> at least as many first-layer spikes (on average).

    This is the causal link behind Fig 7: DVS activity drives SNE work.
    """
    cfg = DEFAULT.firenet
    params = model.init_firenet(cfg)
    rng = np.random.default_rng(0)
    base = rng.random((cfg.in_ch, cfg.height, cfg.width)).astype(np.float32)
    counts = []
    for density in (0.02, 0.1, 0.4):
        x = jnp.asarray((base < density).astype(np.float32)) * 4.0
        _, _, c = model.firenet_step(params, cfg, x, _zeros(cfg.state_shapes))
        counts.append(float(c[0]))
    assert counts == sorted(counts)


def test_firenet_state_carries_over():
    cfg = DEFAULT.firenet
    params = model.init_firenet(cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((cfg.in_ch, cfg.height, cfg.width)).astype(np.float32))
    _, s1, _ = model.firenet_step(params, cfg, x, _zeros(cfg.state_shapes))
    _, s2, _ = model.firenet_step(params, cfg, x, s1)
    # with non-zero input, states must differ between consecutive steps
    assert any(
        not np.allclose(np.asarray(a), np.asarray(b)) for a, b in zip(s1, s2)
    )


def test_firenet_rollout_matches_looped_steps():
    cfg = DEFAULT.firenet
    params = model.init_firenet(cfg)
    rng = np.random.default_rng(2)
    t = 3
    xs = jnp.asarray(
        (rng.random((t, cfg.in_ch, cfg.height, cfg.width)) < 0.05).astype(np.float32)
    )
    flows, final_states, counts = model.firenet_rollout(
        params, cfg, xs, _zeros(cfg.state_shapes)
    )
    states = _zeros(cfg.state_shapes)
    for i in range(t):
        flow, states, c = model.firenet_step(params, cfg, xs[i], states)
        npt.assert_allclose(np.asarray(flows[i]), np.asarray(flow), rtol=1e-4, atol=1e-5)
        npt.assert_allclose(np.asarray(counts[i]), np.asarray(c), rtol=1e-5)
    for a, b in zip(final_states, states):
        npt.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# CUTIE
# ---------------------------------------------------------------------------

def test_cutie_forward_shapes_and_ternary_activations():
    cfg = DEFAULT.cutie
    params = model.init_cutie(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-1, 2, (cfg.in_ch, cfg.in_size, cfg.in_size)).astype(np.float32))
    logits, nz = model.cutie_forward(params, cfg, x)
    assert logits.shape == (cfg.n_classes,)
    assert nz.shape == (cfg.n_layers,)
    assert np.all(np.asarray(nz) >= 0) and np.all(np.asarray(nz) <= 1)


def test_cutie_weights_are_ternary():
    params = model.init_cutie(DEFAULT.cutie)
    for layer in params["layers"]:
        vals = set(np.unique(np.asarray(layer["w"])))
        assert vals <= {-1.0, 0.0, 1.0}
        assert np.all(np.asarray(layer["thr_hi"]) >= np.asarray(layer["thr_lo"]))


def test_cutie_deterministic():
    cfg = DEFAULT.cutie
    p1 = model.init_cutie(cfg)
    p2 = model.init_cutie(cfg)
    x = jnp.ones((cfg.in_ch, cfg.in_size, cfg.in_size))
    l1, _ = model.cutie_forward(p1, cfg, x)
    l2, _ = model.cutie_forward(p2, cfg, x)
    npt.assert_array_equal(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# DroNet
# ---------------------------------------------------------------------------

def test_dronet_forward_shapes():
    cfg = DEFAULT.dronet
    params = model.init_dronet(cfg)
    rng = np.random.default_rng(4)
    x, _, _ = data.corridor_image(rng, cfg.in_size)
    out = model.dronet_forward(params, cfg, jnp.asarray(x))
    assert out.shape == (2,)
    assert np.all(np.isfinite(np.asarray(out)))


def test_dronet_weights_are_int8():
    cfg = DEFAULT.dronet
    params = model.init_dronet(cfg)
    for w in [params["stem"]] + [
        b[k] for b in params["blocks"] for k in ("conv1", "conv2", "skip")
    ]:
        arr = np.asarray(w)
        assert np.all(arr == np.round(arr))
        assert arr.min() >= -128 and arr.max() <= 127


def test_dronet_responds_to_input():
    cfg = DEFAULT.dronet
    params = model.init_dronet(cfg)
    rng = np.random.default_rng(5)
    x1, _, _ = data.corridor_image(rng, cfg.in_size)
    x2, _, _ = data.corridor_image(rng, cfg.in_size)
    o1 = model.dronet_forward(params, cfg, jnp.asarray(x1))
    o2 = model.dronet_forward(params, cfg, jnp.asarray(x2))
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


# ---------------------------------------------------------------------------
# Gesture CSNN
# ---------------------------------------------------------------------------

def test_gesture_step_and_rollout():
    cfg = DEFAULT.gesture
    params = model.init_gesture(cfg)
    ev = data.gesture_events(0, cfg.timesteps, seed=1, size=cfg.in_size)
    logits, counts = model.gesture_rollout(params, cfg, jnp.asarray(ev))
    assert logits.shape == (cfg.n_classes,)
    assert counts.shape == (cfg.timesteps, len(cfg.channels))
    assert float(jnp.sum(counts)) > 0  # a real gesture must spike


def test_gesture_state_shapes_respect_pooling():
    cfg = DEFAULT.gesture
    shapes = model.gesture_state_shapes(cfg)
    assert shapes[0] == (cfg.channels[0], cfg.in_size, cfg.in_size)
    # after two pools the last layer runs at quarter resolution
    assert shapes[-1] == (cfg.channels[-1], cfg.in_size // 4, cfg.in_size // 4)


# ---------------------------------------------------------------------------
# Workload stats (cross-checked against rust/src/nets in integration)
# ---------------------------------------------------------------------------

def test_firenet_stats_consistency():
    cfg = DEFAULT.firenet
    stats = model.firenet_stats(cfg)
    assert len(stats["layers"]) == len(cfg.hidden) + 1
    l0 = stats["layers"][0]
    assert l0["macs"] == cfg.height * cfg.width * cfg.in_ch * cfg.hidden[0] * 9


def test_cutie_stats_consistency():
    cfg = DEFAULT.cutie
    stats = model.cutie_stats(cfg)
    assert len(stats["layers"]) == cfg.n_layers
    # pixel counts follow the pooling schedule: 1024,1024,256,256,64,64,64
    pix = [l["out_pixels"] for l in stats["layers"]]
    assert pix == [1024, 1024, 256, 256, 64, 64, 64]


def test_dronet_stats_positive():
    stats = model.dronet_stats(DEFAULT.dronet)
    assert stats["total_macs"] > 1_000_000
