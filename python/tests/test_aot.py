"""AOT path: the lowered HLO artifacts agree with the live JAX models.

Compiles each emitted HLO text back through the local XLA client and checks
outputs against model.* on random inputs — the exact round-trip the Rust
runtime performs via PJRT.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.common import DEFAULT

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTDIR, "manifest.json")),
    reason="run `make artifacts` first",
)


def _run_hlo(name, inputs):
    """Compile artifacts/<name>.hlo.txt with the in-process CPU client."""
    with open(os.path.join(ARTDIR, f"{name}.hlo.txt")) as f:
        text = f.read()
    client = xc._xla.get_local_backend("cpu") if hasattr(
        xc._xla, "get_local_backend") else jax.devices("cpu")[0].client
    comp = xc._xla.parse_hlo_module_as_computation(text) if hasattr(
        xc._xla, "parse_hlo_module_as_computation") else None
    if comp is None:
        pytest.skip("no HLO-text parser in this jaxlib; rust covers this path")
    exe = client.compile(comp.as_serialized_hlo_module_proto())
    bufs = [jnp.asarray(x) for x in inputs]
    out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


@needs_artifacts
def test_manifest_complete():
    with open(os.path.join(ARTDIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert set(manifest["artifacts"]) == {
        "firenet", "firenet_window", "cutie", "dronet", "gesture"}
    for name, art in manifest["artifacts"].items():
        assert os.path.exists(os.path.join(ARTDIR, art["file"])), name
        assert art["engine"] in {"sne", "cutie", "pulp"}
        for t in art["inputs"] + art["outputs"]:
            assert t["dtype"] == "f32"
            assert all(d > 0 for d in t["shape"])


@needs_artifacts
def test_manifest_hashes_match_files():
    import hashlib

    with open(os.path.join(ARTDIR, "manifest.json")) as f:
        manifest = json.load(f)
    for name, art in manifest["artifacts"].items():
        with open(os.path.join(ARTDIR, art["file"])) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest() == art["sha256"], name


def test_hlo_text_is_parseable_and_stable():
    """Lowering is deterministic: same config -> same HLO text."""
    lowered1, _, _, _ = aot.build_firenet(DEFAULT)
    lowered2, _, _, _ = aot.build_firenet(DEFAULT)
    assert aot.to_hlo_text(lowered1) == aot.to_hlo_text(lowered2)


def test_hlo_constants_not_elided():
    """print_large_constants must stay on: the 0.5.1 HLO text parser reads
    elided `constant({...})` back as ZEROS (all-zero weights on rust side)."""
    lowered, _, _, _ = aot.build_dronet(DEFAULT)
    text = aot.to_hlo_text(lowered)
    assert "constant({...})" not in text
    # and the weight tensors really are inline (DroNet stem is f32[25,16]
    # after reshape; its constant line must carry hundreds of digits)
    assert len(text) > 500_000


def test_hlo_contains_entry_and_no_custom_calls():
    """interpret=True must lower Pallas to plain HLO (no Mosaic custom-calls
    — the rust CPU PJRT client cannot execute those)."""
    for builder in (aot.build_firenet, aot.build_cutie, aot.build_dronet,
                    aot.build_gesture):
        lowered, _, _, _ = builder(DEFAULT)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()


def test_artifact_io_counts():
    _, inputs, outputs, _ = aot.build_firenet(DEFAULT)
    assert len(inputs) == 1 + 4          # events + 4 states
    assert len(outputs) == 1 + 4 + 1     # flow + 4 states + counts
    _, inputs, outputs, _ = aot.build_gesture(DEFAULT)
    assert len(inputs) == 1 + 5 + 1      # events + 5 states + acc
    assert len(outputs) == 5 + 1 + 1


def test_firenet_stats_in_manifest_match_model():
    _, _, _, stats = aot.build_firenet(DEFAULT)
    assert stats == model.firenet_stats(DEFAULT.firenet)
